"""PilotTrainer: training as a Pilot-Data dataflow.

The run is expressed EXACTLY in the paper's nouns (§4.3.2, Fig. 5):

  * the corpus is partitioned into *chunked* shard DUs (partitioned data)
    placed by affinity across Pilot-Data;
  * model state moves through the run as a chain of immutable checkpoint
    DUs carrying a ``replication_factor`` — healing after a pilot loss is
    the runtime's ReplicaManager, not trainer code;
  * each training chunk (N optimizer steps) is a Compute-Unit with
    ``input_data = [shard_du, ckpt_{i-1}]`` and ``output_data = [ckpt_i]``;
  * the Compute-Data Service late-binds each chunk to a pilot co-located
    with its inputs (compute-to-data), re-queues it if a pilot dies
    (restart from ckpt_{i-1} — checkpoint/restart for free), and new pilots
    added mid-run simply start pulling chunks (elastic scaling).

The WHOLE chunk DAG is submitted in one shot through the Session API:
chunk i+1 names chunk i's output DUFuture as an input, the dependency
tracker parks it ``Waiting`` until ckpt_i seals, and under the async
scheduler the released/waiting prefetch hooks overlap chunk i+1's shard
stage-in with chunk i's compute.  The chunk executable holds the jitted
train_step; all cross-chunk state is in DUs, so a chunk can run anywhere —
which is the whole point.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

from ..checkpoint import (
    checkpoint_files,
    decode_array,
    unflatten_tree,
)
from ..configs.base import ModelConfig
from ..core import DataUnitDescription, FUNCTIONS
from ..core.futures import CUFuture, DUFuture
from ..data import (
    Prefetcher,
    SHARD_CHUNK_BYTES,
    ShardReader,
    StreamingShardReader,
    make_token_shards,
    stage_shard_dus,
)
from ..models import build_model
from ..optim import init_adamw
from .train_step import make_train_step


def _restore_from_input(cu_ctx, ckpt_du: str) -> Tuple[Any, Any]:
    """(params, opt_state) from a checkpoint DU staged as a CU input."""
    items_p, items_o = {}, {}
    for rel in cu_ctx.input_manifest(ckpt_du):
        if rel.startswith("params/") and rel.endswith(".npy"):
            items_p[rel[7:-4]] = decode_array(cu_ctx.read_input(ckpt_du, rel))
        elif rel.startswith("opt/") and rel.endswith(".npy"):
            items_o[rel[4:-4]] = decode_array(cu_ctx.read_input(ckpt_du, rel))
    return unflatten_tree(items_p), unflatten_tree(items_o)


class PilotTrainer:
    """Drives a training run as one declaratively-submitted CU/DU DAG.

    ``runtime`` is a :class:`~repro.core.session.Session` or anything that
    exposes one (``PilotManager.session``).  ``ckpt_replication`` is the
    replication factor stamped on every checkpoint DU — with the fault
    manager enabled, the runtime heals each sealed checkpoint to that many
    failure domains, so a mid-run pilot kill costs one chunk replay, not
    the run.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        runtime: Any,
        total_steps: int = 20,
        chunk_steps: int = 5,
        batch: int = 4,
        seq: int = 64,
        peak_lr: float = 1e-3,
        n_shards: int = 2,
        tokens_per_shard: int = 50_000,
        seed: int = 0,
        run_name: str = "pilot-train",
        ckpt_replication: int = 1,
        shard_chunk_bytes: int = SHARD_CHUNK_BYTES,
    ):
        self.cfg = cfg
        # a PilotManager exposes its v2 facade as .session; a Session IS one
        self.session = getattr(runtime, "session", runtime)
        self.total_steps = total_steps
        self.chunk_steps = chunk_steps
        self.batch = batch
        self.seq = seq
        self.peak_lr = peak_lr
        self.n_shards = n_shards
        self.tokens_per_shard = tokens_per_shard
        self.seed = seed
        self.run_name = run_name
        self.ckpt_replication = ckpt_replication
        self.shard_chunk_bytes = shard_chunk_bytes
        self.api = build_model(cfg)
        self.shard_dus: List[DUFuture] = []
        self.ckpt_dus: List[DUFuture] = []
        self.history: List[Dict] = []
        self._register_executable()

    # ------------------------------------------------------------ plumbing
    def _register_executable(self) -> None:
        api = self.api
        me = self

        @functools.lru_cache(maxsize=4)
        def jitted_step(mb: int):
            import jax

            return jax.jit(
                make_train_step(
                    api,
                    peak_lr=me.peak_lr,
                    warmup_steps=max(2, me.total_steps // 10),
                    total_steps=me.total_steps,
                )
            )

        def train_chunk(cu_ctx, shard_du, ckpt_du, start_step, n_steps, batch, seq):
            params, opt_state = _restore_from_input(cu_ctx, ckpt_du)
            # --- data from the co-located shard DU ---
            manifest = cu_ctx.input_manifest(shard_du)
            if any(rel.endswith(".bin") for rel in manifest):
                # chunk-streamable raw shard: consume the canonical byte
                # stream chunk-by-chunk (prefix batches start before the
                # whole shard is local)
                reader = StreamingShardReader(cu_ctx, shard_du)
            else:
                reader = ShardReader.from_cu_context(cu_ctx, shard_du, seed=me.seed)
            batches = Prefetcher(
                reader.batches(batch, seq, start_step=start_step), depth=2
            )
            step_fn = jitted_step(1)
            losses = []
            try:
                for _, b in zip(range(n_steps), batches):
                    params, opt_state, metrics = step_fn(params, opt_state, b)
                    losses.append(float(metrics["loss"]))
            finally:
                batches.close()
            # --- emit the next checkpoint DU ---
            for rel, data in checkpoint_files(
                start_step + n_steps, me.run_name, params, opt_state
            ).items():
                cu_ctx.write_output(rel, data)
            return {"losses": losses, "final_loss": losses[-1] if losses else None}

        FUNCTIONS.register(f"train_chunk:{self.run_name}", train_chunk)

    # ---------------------------------------------------------------- setup
    def stage_data(self, affinities: Optional[List[Optional[str]]] = None) -> None:
        """Create + place the shard DUs (partitioned-data pattern): raw
        chunk-streamable format, chunked manifests, affinity round-robin."""
        shards = make_token_shards(
            self.n_shards,
            self.tokens_per_shard,
            self.cfg.vocab_size,
            seed=self.seed,
            fmt="raw",
        )
        self.shard_dus = stage_shard_dus(
            self.session,
            shards,
            name=self.run_name,
            affinities=affinities,
            chunk_size=self.shard_chunk_bytes,
        )

    def initial_checkpoint(self) -> DUFuture:
        """ckpt_0 from fresh init (also a DU, so chunk 0 is uniform)."""
        import jax

        params = self.api.init(jax.random.PRNGKey(self.seed))
        opt_state = init_adamw(params)
        fut = self.session.submit_du(
            name=f"{self.run_name}.ckpt{0:08d}",
            files=checkpoint_files(0, self.run_name, params, opt_state),
            replication_factor=self.ckpt_replication,
        )
        self.session.store.hset(f"ckpt:{self.run_name}", f"{0:08d}", fut.id)
        self.ckpt_dus.append(fut)
        return fut

    # ----------------------------------------------------------------- run
    def submit_dag(self) -> List[Tuple[int, int, int, CUFuture]]:
        """Submit the ENTIRE chunk chain upfront — one shot, no user-side
        waits between chunks.  Each chunk's checkpoint input is the
        previous chunk's output DUFuture; the runtime's DU-readiness gate
        sequences the chain and (async mode) pipelines the stage-ins.

        Returns ``[(chunk_idx, start_step, n_steps, cu_future), ...]``."""
        if not self.shard_dus:
            self.stage_data()
        ckpt = self.ckpt_dus[-1] if self.ckpt_dus else self.initial_checkpoint()
        chunks = []
        step = 0
        chunk_idx = 0
        while step < self.total_steps:
            n = min(self.chunk_steps, self.total_steps - step)
            shard = self.shard_dus[chunk_idx % len(self.shard_dus)]
            # NOTE: no hard affinity constraint — data locality is a SOFT
            # preference expressed through the CDS's input-data scoring
            # (§6.1); a hard constraint would pin chunks to a site even
            # after its pilots die, defeating failover.
            cu = self.session.submit_cu(
                executable=f"train_chunk:{self.run_name}",
                args=(shard.id, ckpt.id, step, n, self.batch, self.seq),
                input_data=[shard, ckpt],
                output_data=[
                    DataUnitDescription(
                        name=f"{self.run_name}.ckpt{step + n:08d}",
                        replication_factor=self.ckpt_replication,
                    )
                ],
                max_retries=4,
            )
            chunks.append((chunk_idx, step, n, cu))
            ckpt = cu.output
            step += n
            chunk_idx += 1
        return chunks

    def run(self, timeout_per_chunk: float = 300.0) -> Dict[str, Any]:
        """Submit the one-shot DAG, then collect; returns the loss summary."""
        chunks = self.submit_dag()
        for chunk_idx, step, n, cu in chunks:
            res = cu.result(timeout=timeout_per_chunk)
            self.history.append(
                {
                    "chunk": chunk_idx,
                    "steps": (step, step + n),
                    "pilot": cu.pilot_id,
                    "losses": res["losses"],
                    "t_s_sim": cu.timings.sim_stage_s,
                }
            )
            self.ckpt_dus.append(cu.output)
            self.session.store.hset(
                f"ckpt:{self.run_name}", f"{step + n:08d}", cu.output.id
            )
        first = self.history[0]["losses"][0]
        last = self.history[-1]["losses"][-1]
        return {
            "steps": self.total_steps,
            "chunks": len(chunks),
            "first_loss": first,
            "final_loss": last,
            "improved": last < first,
            "pilots_used": sorted({h["pilot"] for h in self.history}),
            "history": self.history,
        }

    def restore_params(self) -> Any:
        """Load params from the latest checkpoint DU (resharding restore)."""
        from ..checkpoint import load_checkpoint_du

        ctx = self.session.ctx
        du = ctx.lookup(self.ckpt_dus[-1].id)
        _, params, _ = load_checkpoint_du(ctx, du)
        return params
