"""PilotTrainer: training as a Pilot-Data dataflow.

The run is expressed EXACTLY in the paper's nouns (§4.3.2, Fig. 5):

  * the corpus is partitioned into shard DUs (partitioned data) placed by
    affinity across Pilot-Data;
  * model state moves through the run as a chain of immutable checkpoint
    DUs;
  * each training chunk (N optimizer steps) is a Compute-Unit with
    ``input_data = [shard_du, ckpt_{i-1}]`` and ``output_data = [ckpt_i]``;
  * the Compute-Data Service late-binds each chunk to a pilot co-located
    with its inputs (compute-to-data), re-queues it if a pilot dies
    (restart from ckpt_{i-1} — checkpoint/restart for free), and new pilots
    added mid-run simply start pulling chunks (elastic scaling).

The chunk executable holds the jitted train_step; all cross-chunk state is
in DUs, so a chunk can run anywhere — which is the whole point.
"""

from __future__ import annotations

import functools
import io
import json
from typing import Any, Dict, List, Optional

import numpy as np

from ..configs.base import ModelConfig
from ..core import (
    ComputeUnitDescription,
    CUState,
    DataUnit,
    DataUnitDescription,
    FUNCTIONS,
    PilotManager,
)
from ..data import Prefetcher, ShardReader, make_token_shards
from ..models import build_model
from ..optim import init_adamw
from .train_step import make_train_step


def _encode(arr) -> bytes:
    buf = io.BytesIO()
    np.save(buf, np.asarray(arr), allow_pickle=False)
    return buf.getvalue()


def _decode(data: bytes) -> np.ndarray:
    return np.load(io.BytesIO(data), allow_pickle=False)


def _flatten(tree: Any, prefix: str = "") -> List:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out.extend(_flatten(tree[k], f"{prefix}{k}/"))
        return out
    return [(prefix.rstrip("/"), tree)]


def _unflatten(items: Dict[str, Any]) -> Any:
    root: Dict[str, Any] = {}
    for path, value in items.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    return root


class PilotTrainer:
    def __init__(
        self,
        cfg: ModelConfig,
        manager: PilotManager,
        total_steps: int = 20,
        chunk_steps: int = 5,
        batch: int = 4,
        seq: int = 64,
        peak_lr: float = 1e-3,
        n_shards: int = 2,
        tokens_per_shard: int = 50_000,
        seed: int = 0,
        run_name: str = "pilot-train",
    ):
        self.cfg = cfg
        self.mgr = manager
        self.total_steps = total_steps
        self.chunk_steps = chunk_steps
        self.batch = batch
        self.seq = seq
        self.peak_lr = peak_lr
        self.n_shards = n_shards
        self.tokens_per_shard = tokens_per_shard
        self.seed = seed
        self.run_name = run_name
        self.api = build_model(cfg)
        self.shard_dus: List[DataUnit] = []
        self.ckpt_dus: List[DataUnit] = []
        self.history: List[Dict] = []
        self._register_executable()

    # ------------------------------------------------------------ plumbing
    def _register_executable(self) -> None:
        api = self.api
        me = self

        @functools.lru_cache(maxsize=4)
        def jitted_step(mb: int):
            import jax

            return jax.jit(
                make_train_step(
                    api,
                    peak_lr=me.peak_lr,
                    warmup_steps=max(2, me.total_steps // 10),
                    total_steps=me.total_steps,
                )
            )

        def train_chunk(cu_ctx, shard_du, ckpt_du, start_step, n_steps, batch, seq):
            import jax

            # --- restore model state from the previous checkpoint DU ---
            manifest = cu_ctx.input_manifest(ckpt_du)
            items_p, items_o = {}, {}
            for rel in manifest:
                if rel.startswith("params/") and rel.endswith(".npy"):
                    items_p[rel[7:-4]] = _decode(cu_ctx.read_input(ckpt_du, rel))
                elif rel.startswith("opt/") and rel.endswith(".npy"):
                    items_o[rel[4:-4]] = _decode(cu_ctx.read_input(ckpt_du, rel))
            params = _unflatten(items_p)
            opt_state = _unflatten(items_o)
            # --- data from the co-located shard DU ---
            reader = ShardReader.from_cu_context(
                cu_ctx, shard_du, seed=me.seed + start_step
            )
            batches = Prefetcher(reader.batches(batch, seq), depth=2)
            step_fn = jitted_step(1)
            losses = []
            for i, b in zip(range(n_steps), batches):
                params, opt_state, metrics = step_fn(params, opt_state, b)
                losses.append(float(metrics["loss"]))
            batches.close()
            # --- emit the next checkpoint DU ---
            cu_ctx.write_output(
                "meta.json",
                json.dumps(
                    {"step": start_step + n_steps, "run": me.run_name}
                ).encode(),
            )
            for path, leaf in _flatten({"params": params}):
                cu_ctx.write_output(f"{path}.npy", _encode(leaf))
            for path, leaf in _flatten({"opt": opt_state}):
                cu_ctx.write_output(f"{path}.npy", _encode(leaf))
            return {"losses": losses, "final_loss": losses[-1] if losses else None}

        FUNCTIONS.register(f"train_chunk:{self.run_name}", train_chunk)

    # ---------------------------------------------------------------- setup
    def stage_data(self, affinities: Optional[List[Optional[str]]] = None) -> None:
        """Create + place the shard DUs (partitioned-data pattern)."""
        shards = make_token_shards(
            self.n_shards,
            self.tokens_per_shard,
            self.cfg.vocab_size,
            seed=self.seed,
        )
        for i, files in enumerate(shards):
            aff = affinities[i % len(affinities)] if affinities else None
            du = self.mgr.cds.submit_data_unit(
                DataUnitDescription(
                    name=f"{self.run_name}.shard{i}", files=files, affinity=aff
                )
            )
            self.shard_dus.append(du)

    def initial_checkpoint(self) -> DataUnit:
        """ckpt_0 from fresh init (also a DU, so chunk 0 is uniform)."""
        import jax

        params = self.api.init(jax.random.PRNGKey(self.seed))
        opt_state = init_adamw(params)
        files = {"meta.json": json.dumps({"step": 0, "run": self.run_name}).encode()}
        for path, leaf in _flatten({"params": params}):
            files[f"{path}.npy"] = _encode(leaf)
        for path, leaf in _flatten({"opt": opt_state}):
            files[f"{path}.npy"] = _encode(leaf)
        du = self.mgr.cds.submit_data_unit(
            DataUnitDescription(name=f"{self.run_name}.ckpt0", files=files)
        )
        self.ckpt_dus.append(du)
        return du

    # ----------------------------------------------------------------- run
    def run(self, timeout_per_chunk: float = 300.0) -> Dict[str, Any]:
        """Drive the chunk chain; returns summary with loss history."""
        if not self.shard_dus:
            self.stage_data()
        ckpt = self.ckpt_dus[-1] if self.ckpt_dus else self.initial_checkpoint()
        step = 0
        chunk_idx = 0
        while step < self.total_steps:
            n = min(self.chunk_steps, self.total_steps - step)
            shard = self.shard_dus[chunk_idx % len(self.shard_dus)]
            out_du = self.mgr.cds.submit_data_unit(
                DataUnitDescription(
                    name=f"{self.run_name}.ckpt{step + n}",
                )
            )
            # NOTE: no hard affinity constraint — data locality is a SOFT
            # preference expressed through the CDS's input-data scoring
            # (§6.1); a hard constraint would pin chunks to a site even
            # after its pilots die, defeating failover.
            cu = self.mgr.cds.submit_compute_unit(
                ComputeUnitDescription(
                    executable=f"train_chunk:{self.run_name}",
                    args=(shard.id, ckpt.id, step, n, self.batch, self.seq),
                    input_data=[shard.id, ckpt.id],
                    output_data=[out_du.id],
                    max_retries=4,
                )
            )
            state = cu.wait(timeout=timeout_per_chunk)
            if state != CUState.DONE:
                raise RuntimeError(
                    f"chunk {chunk_idx} failed: {state} ({cu.error})"
                )
            self.history.append(
                {
                    "chunk": chunk_idx,
                    "steps": (step, step + n),
                    "pilot": cu.pilot_id,
                    "losses": cu.result["losses"],
                    "t_s_sim": cu.timings.sim_stage_s,
                }
            )
            self.ckpt_dus.append(out_du)
            ckpt = out_du
            step += n
            chunk_idx += 1
        first = self.history[0]["losses"][0]
        last = self.history[-1]["losses"][-1]
        return {
            "steps": step,
            "chunks": chunk_idx,
            "first_loss": first,
            "final_loss": last,
            "improved": last < first,
            "pilots_used": sorted({h["pilot"] for h in self.history}),
            "history": self.history,
        }

    def restore_params(self) -> Any:
        """Load params from the latest checkpoint DU (resharding restore)."""
        du = self.ckpt_dus[-1]
        pd = self.mgr.ctx.lookup(du.locations[0])
        items = {}
        for rel in du.manifest:
            if rel.startswith("params/") and rel.endswith(".npy"):
                items[rel[7:-4]] = _decode(pd.fetch_du_file(du.id, rel))
        return _unflatten(items)
