"""URL-scheme → adaptor registry (paper: "The URL scheme is used to select
an appropriate BigJob adaptor")."""

from __future__ import annotations

import urllib.parse
from typing import Dict, Optional, Type

from .base import BackendProfile, StorageAdaptor
from .local_fs import LocalFSBackend, SharedFSBackend
from .memory import MemoryBackend
from .object_store import ObjectStoreBackend

_REGISTRY: Dict[str, Type[StorageAdaptor]] = {}


def register_backend(cls: Type[StorageAdaptor]) -> Type[StorageAdaptor]:
    if not cls.scheme:
        raise ValueError("backend class must define a scheme")
    _REGISTRY[cls.scheme] = cls
    return cls


for _cls in (MemoryBackend, LocalFSBackend, SharedFSBackend, ObjectStoreBackend):
    register_backend(_cls)


def make_backend(
    url: str, profile: Optional[BackendProfile] = None, **kwargs
) -> StorageAdaptor:
    scheme = urllib.parse.urlparse(url).scheme
    if scheme not in _REGISTRY:
        raise ValueError(
            f"no storage adaptor for scheme {scheme!r} "
            f"(available: {sorted(_REGISTRY)})"
        )
    return _REGISTRY[scheme](url, profile=profile, **kwargs)


def available_schemes() -> list:
    return sorted(_REGISTRY)
