"""Storage adaptor base — the paper's adaptor pattern (§4.2).

"A resource adaptor encapsulates the different infrastructure-specific
semantics of the backend system ... in the case of Pilot-Data different
storage types (e.g. file vs. object storage), access and transfer
protocols."  The URL scheme selects the adaptor (paper: "The URL scheme is
used to select an appropriate BigJob adaptor").

Each adaptor also declares a performance profile (effective bandwidth,
per-operation latency) used by the simulated transfer clock so benchmarks
can reproduce the paper's backend comparisons (Fig. 7) deterministically on
a single node.  The profiles mirror the *relative* characteristics the paper
measured: GridFTP/SRM-class bulk bandwidth, SSH-class low setup cost,
service-layer (Globus-Online-class) per-request overhead, WAN-constrained
object stores.
"""

from __future__ import annotations

import abc
import dataclasses
import urllib.parse
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class BackendProfile:
    """Performance profile for the simulated transfer clock."""

    bandwidth: float  # bytes/sec sustained
    op_latency: float  # fixed per-operation setup cost, seconds
    register_latency: float = 0.0  # catalog/registration cost per file


class StorageAdaptor(abc.ABC):
    """Uniform interface over heterogeneous storage backends.

    Keys are container-relative POSIX-ish paths (``a/b/c``).  Object-store
    adaptors may restrict the namespace (see ``flat_namespace``), mirroring
    the paper's note that cloud stores "provide only a namespace with a
    1-level hierarchy".
    """

    scheme: str = ""
    flat_namespace: bool = False

    def __init__(self, url: str, profile: Optional[BackendProfile] = None):
        self.url = url
        parsed = urllib.parse.urlparse(url)
        if parsed.scheme != self.scheme:
            raise ValueError(
                f"{type(self).__name__} expects scheme {self.scheme!r}, got {url!r}"
            )
        self.location = parsed.netloc  # affinity label host part
        self.container = parsed.path.lstrip("/")
        self.profile = profile or self.default_profile()

    # ------------------------------------------------------------ abstract
    @classmethod
    @abc.abstractmethod
    def default_profile(cls) -> BackendProfile: ...

    @abc.abstractmethod
    def put(self, key: str, data: bytes) -> int:
        """Store bytes under key; returns size stored."""

    @abc.abstractmethod
    def get(self, key: str) -> bytes: ...

    @abc.abstractmethod
    def delete(self, key: str) -> None: ...

    @abc.abstractmethod
    def list(self, prefix: str = "") -> List[str]: ...

    @abc.abstractmethod
    def exists(self, key: str) -> bool: ...

    # ------------------------------------------------------------- helpers
    def validate_key(self, key: str) -> str:
        if not key or key.startswith("/") or ".." in key.split("/"):
            raise ValueError(f"bad storage key {key!r}")
        if self.flat_namespace and "/" in key:
            # 1-level hierarchy (S3-style): flatten with an encoded separator.
            key = key.replace("/", "%2F")
        return key

    def size(self, key: str) -> int:
        return len(self.get(key))

    def total_bytes(self) -> int:
        return sum(self.size(k) for k in self.list())

    def simulated_put_time(self, nbytes: int) -> float:
        p = self.profile
        return p.op_latency + nbytes / p.bandwidth + p.register_latency

    def simulated_get_time(self, nbytes: int) -> float:
        p = self.profile
        return p.op_latency + nbytes / p.bandwidth

    def __repr__(self) -> str:  # pragma: no cover
        return f"<{type(self).__name__} {self.url}>"


#: reserved sub-namespace for a DU's physical chunk stream inside a PD
#: container (no legal DU-relative file path can collide: path segments of
#: ``.c`` style dot-names are still valid, but the chunk files carry a
#: fixed-width numeric name under it that the file layer never writes)
CHUNK_DIR = ".c"


def chunk_key(du_id: str, index: int) -> str:
    """Backend key for chunk ``index`` of DU ``du_id``.

    The chunk — not the file — is the unit of physical storage: adaptors
    see a flat sequence of same-sized objects per DU, which is what makes
    partial replicas and ranged/striped transfers expressible on flat
    object stores (the paper's 1-level-hierarchy caveat) exactly as on
    hierarchical ones.
    """
    return f"{du_id}/{CHUNK_DIR}/{index:08d}"


def parse_chunk_key(key: str) -> Optional[Tuple[str, int]]:
    """Inverse of :func:`chunk_key`; None if ``key`` is not a chunk key."""
    parts = key.split("/")
    if len(parts) < 3 or parts[-2] != CHUNK_DIR:
        return None
    try:
        return "/".join(parts[:-2]), int(parts[-1])
    except ValueError:
        return None


class StorageError(RuntimeError):
    pass


class KeyNotFound(StorageError):
    pass


def join_meta(d: Dict[str, str]) -> str:
    return urllib.parse.urlencode(d)
