from .base import (
    CHUNK_DIR,
    BackendProfile,
    KeyNotFound,
    StorageAdaptor,
    StorageError,
    chunk_key,
    parse_chunk_key,
)
from .local_fs import LocalFSBackend, SharedFSBackend
from .memory import MemoryBackend
from .object_store import ObjectStoreBackend
from .registry import available_schemes, make_backend, register_backend

__all__ = [
    "BackendProfile",
    "CHUNK_DIR",
    "chunk_key",
    "parse_chunk_key",
    "KeyNotFound",
    "StorageAdaptor",
    "StorageError",
    "LocalFSBackend",
    "SharedFSBackend",
    "MemoryBackend",
    "ObjectStoreBackend",
    "available_schemes",
    "make_backend",
    "register_backend",
]
