from .base import BackendProfile, KeyNotFound, StorageAdaptor, StorageError
from .local_fs import LocalFSBackend, SharedFSBackend
from .memory import MemoryBackend
from .object_store import ObjectStoreBackend
from .registry import available_schemes, make_backend, register_backend

__all__ = [
    "BackendProfile",
    "KeyNotFound",
    "StorageAdaptor",
    "StorageError",
    "LocalFSBackend",
    "SharedFSBackend",
    "MemoryBackend",
    "ObjectStoreBackend",
    "available_schemes",
    "make_backend",
    "register_backend",
]
