"""In-memory storage adaptor (``mem://host/container``).

The fastest tier — host-DRAM caches and transient intermediate data (paper
§4.1 usage mode 2: "short-term, transient 'storage space' for intermediate
data, which can be removed after the end of the application run").
"""

from __future__ import annotations

import threading
from typing import Dict, List

from .base import BackendProfile, KeyNotFound, StorageAdaptor

# Shared across adaptor instances so that two PDs pointing at the same
# mem://host/container see the same data (like a shared filesystem would).
_STORES: Dict[str, Dict[str, bytes]] = {}
_LOCK = threading.Lock()


class MemoryBackend(StorageAdaptor):
    scheme = "mem"

    @classmethod
    def default_profile(cls) -> BackendProfile:
        # Host DRAM-class: very high bandwidth, negligible latency.
        return BackendProfile(bandwidth=20e9, op_latency=1e-6)

    def __init__(self, url: str, profile=None):
        super().__init__(url, profile)
        with _LOCK:
            self._store = _STORES.setdefault(
                f"{self.location}/{self.container}", {}
            )
        self._lock = threading.Lock()

    def put(self, key: str, data: bytes) -> int:
        key = self.validate_key(key)
        with self._lock:
            self._store[key] = bytes(data)
        return len(data)

    def get(self, key: str) -> bytes:
        key = self.validate_key(key)
        with self._lock:
            if key not in self._store:
                raise KeyNotFound(f"{self.url}: {key}")
            return self._store[key]

    def delete(self, key: str) -> None:
        key = self.validate_key(key)
        with self._lock:
            self._store.pop(key, None)

    def list(self, prefix: str = "") -> List[str]:
        with self._lock:
            return sorted(k for k in self._store if k.startswith(prefix))

    def exists(self, key: str) -> bool:
        key = self.validate_key(key)
        with self._lock:
            return key in self._store

    def size(self, key: str) -> int:
        key = self.validate_key(key)
        with self._lock:
            if key not in self._store:
                raise KeyNotFound(f"{self.url}: {key}")
            return len(self._store[key])
