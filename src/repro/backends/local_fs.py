"""Filesystem storage adaptors.

``file://<host>/<abs-or-rel-root>`` — a directory on one host (the paper's
SSH-to-a-directory backend: cheap setup, moderate bandwidth).

``sharedfs://<site>/<root>`` — a parallel/shared filesystem mounted across a
site (the paper's Lustre-scratch-on-Lonestar backend, scenario 4): higher
sustained bandwidth, visible to every host in the site subtree, so a DU in a
shared-FS PD resolves as a logical link for any pilot in that site.
"""

from __future__ import annotations

import os
import tempfile
import threading
from typing import List

from .base import BackendProfile, KeyNotFound, StorageAdaptor

_SANDBOX = os.environ.get(
    "REPRO_STORAGE_ROOT", os.path.join(tempfile.gettempdir(), "repro_storage")
)


class LocalFSBackend(StorageAdaptor):
    scheme = "file"

    @classmethod
    def default_profile(cls) -> BackendProfile:
        # SSH/scp-class: low setup cost, moderate bandwidth (paper Fig. 7:
        # "For smaller data volumes SSH is a better choice").
        return BackendProfile(bandwidth=0.8e9, op_latency=0.05)

    def __init__(self, url: str, profile=None):
        super().__init__(url, profile)
        root = self.container or "default"
        self.root = os.path.join(_SANDBOX, self.scheme, self.location, root)
        os.makedirs(self.root, exist_ok=True)
        self._lock = threading.Lock()

    def _path(self, key: str) -> str:
        key = self.validate_key(key)
        return os.path.join(self.root, key.replace("%2F", "/"))

    def put(self, key: str, data: bytes) -> int:
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with self._lock, open(path, "wb") as fh:
            fh.write(data)
        return len(data)

    def get(self, key: str) -> bytes:
        path = self._path(key)
        if not os.path.exists(path):
            raise KeyNotFound(f"{self.url}: {key}")
        with open(path, "rb") as fh:
            return fh.read()

    def delete(self, key: str) -> None:
        path = self._path(key)
        if os.path.exists(path):
            os.remove(path)

    def list(self, prefix: str = "") -> List[str]:
        out = []
        for dirpath, _, files in os.walk(self.root):
            for f in files:
                rel = os.path.relpath(os.path.join(dirpath, f), self.root)
                rel = rel.replace(os.sep, "/")
                if rel.startswith(prefix):
                    out.append(rel)
        return sorted(out)

    def exists(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def size(self, key: str) -> int:
        path = self._path(key)
        if not os.path.exists(path):
            raise KeyNotFound(f"{self.url}: {key}")
        return os.path.getsize(path)


class SharedFSBackend(LocalFSBackend):
    scheme = "sharedfs"

    @classmethod
    def default_profile(cls) -> BackendProfile:
        # Parallel-FS-class (GridFTP-to-Lustre in the paper): high sustained
        # bandwidth, some per-op cost.
        return BackendProfile(bandwidth=4e9, op_latency=0.02)
