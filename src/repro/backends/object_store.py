"""Object-store adaptor (``object://region/bucket``) — the paper's S3 class.

Properties mirrored from §2.2's discussion of cloud object stores:
  * flat, 1-level namespace (keys with ``/`` are transparently encoded),
  * write-once/read-many orientation (overwrite of an existing key raises
    unless versioning is enabled),
  * WAN-constrained ingest bandwidth with per-request latency (paper Fig. 7:
    "S3 is constrained by the limited bandwidth available to the Amazon
    datacenter", T_S grows linearly),
  * region-internal replication is "free" (the store itself replicates
    within a region — paper: "Amazon S3 automatically replicates data across
    multiple data centers within a region").
"""

from __future__ import annotations

import threading
from typing import Dict, List

from .base import BackendProfile, KeyNotFound, StorageAdaptor, StorageError

_BUCKETS: Dict[str, Dict[str, bytes]] = {}
_LOCK = threading.Lock()


class ObjectStoreBackend(StorageAdaptor):
    scheme = "object"
    flat_namespace = True

    def __init__(self, url: str, profile=None, versioning: bool = False):
        super().__init__(url, profile)
        self.versioning = versioning
        with _LOCK:
            self._bucket = _BUCKETS.setdefault(
                f"{self.location}/{self.container}", {}
            )
        self._lock = threading.Lock()

    @classmethod
    def default_profile(cls) -> BackendProfile:
        # WAN-constrained: modest bandwidth, request latency, catalog cost.
        return BackendProfile(
            bandwidth=0.25e9, op_latency=0.12, register_latency=0.01
        )

    def put(self, key: str, data: bytes) -> int:
        key = self.validate_key(key)
        with self._lock:
            if key in self._bucket and not self.versioning:
                raise StorageError(
                    f"object store is write-once ({key!r} exists; "
                    "enable versioning to overwrite)"
                )
            self._bucket[key] = bytes(data)
        return len(data)

    def get(self, key: str) -> bytes:
        key = self.validate_key(key)
        with self._lock:
            if key not in self._bucket:
                raise KeyNotFound(f"{self.url}: {key}")
            return self._bucket[key]

    def delete(self, key: str) -> None:
        key = self.validate_key(key)
        with self._lock:
            self._bucket.pop(key, None)

    def list(self, prefix: str = "") -> List[str]:
        prefix = prefix.replace("/", "%2F") if prefix else prefix
        with self._lock:
            return sorted(k for k in self._bucket if k.startswith(prefix))

    def exists(self, key: str) -> bool:
        key = self.validate_key(key)
        with self._lock:
            return key in self._bucket

    def size(self, key: str) -> int:
        key = self.validate_key(key)
        with self._lock:
            if key not in self._bucket:
                raise KeyNotFound(f"{self.url}: {key}")
            return len(self._bucket[key])
