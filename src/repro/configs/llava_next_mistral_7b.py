"""llava-next-mistral-7b [vlm] — mistral-7b backbone, anyres tiling.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
The vision tower is a STUB per the assignment: input_specs() provides
precomputed patch embeddings [B, n_patches, d_model] that are prepended
to the token embeddings.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""

from .base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    pattern=("attn",),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    vlm=VLMConfig(n_patches=576),
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
