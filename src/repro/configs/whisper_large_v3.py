"""whisper-large-v3 [audio] — encoder-decoder transformer backbone.

32L (enc) + 32L (dec), d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866.
The conv audio frontend is a STUB per the assignment: input_specs()
provides precomputed frame embeddings [B, n_frames, d_model].
Positional encoding: RoPE on the backbone (hardware adaptation note in
DESIGN.md — original uses learned absolute embeddings; backbone compute
is unchanged).
[arXiv:2212.04356; unverified]
"""

from .base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    pattern=("attn",),
    rope_theta=10000.0,
    tie_embeddings=True,
    mlp_type="gelu",
    encdec=EncDecConfig(n_enc_layers=32, n_frames=1500),
    source="arXiv:2212.04356; unverified",
)
