"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000 ssm_state=64
[arXiv:2411.15242; hf]
"""

from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    # mamba backbone; ONE shared transformer block re-applied every 6th
    # layer (weights shared across occurrences — zamba2's design)
    pattern=("mamba",) * 5 + ("shared_attn",),
    rope_theta=10000.0,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, n_groups=1),
    source="arXiv:2411.15242; hf",
)
