"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144
[hf:google/gemma-3-1b-pt; unverified]
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    pattern=("swa",) * 5 + ("global",),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)
