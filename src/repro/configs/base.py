"""Config system: architecture + shape + run configuration.

Every assigned architecture is a :class:`ModelConfig` in its own module
(``repro.configs.<arch>``); shapes are :class:`ShapeConfig` (assignment's
train_4k / prefill_32k / decode_32k / long_500k).  ``reduced()`` derives the
CPU-smoke-test variant of any config (same family/block pattern, tiny
dims).

This module is dependency-light (no jax import) so launchers can read
configs before touching jax.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 0
    n_frames: int = 1500  # precomputed frame embeddings (conv frontend stub)


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    n_patches: int = 576  # precomputed patch embeddings (vision tower stub)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // n_heads
    #: per-layer block pattern, cycled over the depth.  Entries:
    #:   "attn"    — full causal attention + dense MLP
    #:   "swa"     — sliding-window attention + dense MLP
    #:   "global"  — full attention (gemma local:global naming) + dense MLP
    #:   "moe"     — full attention + MoE MLP
    #:   "swa_moe" — sliding-window attention + MoE MLP
    #:   "mamba"   — Mamba2 SSD mixer (no MLP)
    #:   "shared_attn" — attention block with weights SHARED across all
    #:                   occurrences (zamba2-style)
    pattern: Tuple[str, ...] = ("attn",)
    sliding_window: int = 1024
    rope_theta: float = 10000.0
    #: RoPE base for sliding-window ("swa") blocks; gemma3 uses 10k local
    #: vs 1M global.  0.0 → same as rope_theta.
    rope_theta_local: float = 0.0
    #: "swiglu" (3 matrices) or "gelu" (2 matrices, whisper-style)
    mlp_type: str = "swiglu"
    #: KV cache storage dtype: "bfloat16" or "int8" (per-token-per-head
    #: symmetric quantization; §Perf decode lever)
    kv_cache_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    #: does any block attend with an unbounded (full) window?
    #: (drives the long_500k applicability rule)
    source: str = ""

    # ------------------------------------------------------------ derived
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return all(p == "mamba" for p in self.pattern)

    @property
    def is_pure_full_attention(self) -> bool:
        """True if every attention block is full/unwindowed (assignment's
        long_500k skip rule)."""
        att = {p for p in self.pattern if p != "mamba"}
        return bool(att) and att <= {"attn", "global", "moe"}

    @property
    def supports_long_context(self) -> bool:
        return not self.is_pure_full_attention

    def layer_kinds(self) -> Tuple[str, ...]:
        """The concrete per-layer kinds for the full depth."""
        reps = math.ceil(self.n_layers / len(self.pattern))
        return (self.pattern * reps)[: self.n_layers]

    # -------------------------------------------------------- param counts
    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.head_dim_
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        attn = q + kv + o
        mlp_mats = 3 if self.mlp_type == "swiglu" else 2
        mlp_dense = mlp_mats * d * self.d_ff
        total = 0
        if self.encdec is not None and self.encdec.n_enc_layers:
            # encoder stack + per-decoder-layer cross-attention
            total += self.encdec.n_enc_layers * (attn + mlp_dense + 2 * d)
            total += self.n_layers * (attn + d)
        shared_counted = False
        for kind in self.layer_kinds():
            if kind == "mamba":
                total += self._mamba_params()
            elif kind == "shared_attn":
                if not shared_counted:
                    total += attn + mlp_dense + 2 * d
                    shared_counted = True
            elif kind in ("moe", "swa_moe"):
                assert self.moe is not None
                total += attn + 2 * d
                total += self.moe.n_experts * 3 * d * self.moe.d_ff_expert
                total += d * self.moe.n_experts  # router
            else:
                total += attn + mlp_dense + 2 * d
        total += self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        inactive = (
            (self.moe.n_experts - self.moe.top_k)
            * 3
            * d
            * self.moe.d_ff_expert
        )
        n_moe_layers = sum(1 for k in self.layer_kinds() if k in ("moe", "swa_moe"))
        return total - n_moe_layers * inactive

    def _mamba_params(self) -> int:
        assert self.ssm is not None
        d = self.d_model
        s = self.ssm
        d_in = s.d_inner(d)
        nh = s.n_heads(d)
        in_proj = d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
        conv = s.conv_width * (d_in + 2 * s.n_groups * s.d_state)
        out_proj = d_in * d
        extras = nh * 3 + d_in + 2 * d  # A, D, dt_bias, gate-norm, norms
        return in_proj + conv + out_proj + extras


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


#: the assignment's four shapes (shared by every LM arch)
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Derive a tiny same-family config for CPU smoke tests."""
    pattern_len = len(cfg.pattern)
    small = dict(
        n_layers=max(pattern_len, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 1,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        sliding_window=32,
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.moe is not None:
        small["moe"] = MoEConfig(
            n_experts=min(cfg.moe.n_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=32,
        )
    if cfg.ssm is not None:
        small["ssm"] = SSMConfig(
            d_state=16, head_dim=16, expand=2, n_groups=1, conv_width=4, chunk=16
        )
    if cfg.encdec is not None:
        small["encdec"] = EncDecConfig(n_enc_layers=2, n_frames=16)
    if cfg.vlm is not None:
        small["vlm"] = VLMConfig(n_patches=8)
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)


SMOKE_SHAPE = ShapeConfig("smoke", seq_len=64, global_batch=2, kind="train")
