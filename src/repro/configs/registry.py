"""Architecture registry: --arch <id> → ModelConfig."""

from __future__ import annotations

from typing import Dict, List

from .base import ModelConfig, SHAPES, ShapeConfig, reduced

from .granite_34b import CONFIG as granite_34b
from .gemma3_12b import CONFIG as gemma3_12b
from .h2o_danube_1_8b import CONFIG as h2o_danube_1_8b
from .gemma3_1b import CONFIG as gemma3_1b
from .granite_moe_3b_a800m import CONFIG as granite_moe_3b_a800m
from .qwen3_moe_30b_a3b import CONFIG as qwen3_moe_30b_a3b
from .zamba2_1_2b import CONFIG as zamba2_1_2b
from .whisper_large_v3 import CONFIG as whisper_large_v3
from .llava_next_mistral_7b import CONFIG as llava_next_mistral_7b
from .mamba2_370m import CONFIG as mamba2_370m

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        granite_34b,
        gemma3_12b,
        h2o_danube_1_8b,
        gemma3_1b,
        granite_moe_3b_a800m,
        qwen3_moe_30b_a3b,
        zamba2_1_2b,
        whisper_large_v3,
        llava_next_mistral_7b,
        mamba2_370m,
    )
}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return reduced(get_config(name[: -len("-smoke")]))
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def list_archs() -> List[str]:
    return sorted(ARCHS)


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """The assignment's skip rule: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k":
        return cfg.supports_long_context
    return True
