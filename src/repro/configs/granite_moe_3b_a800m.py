"""granite-moe-3b-a800m [moe] — 40 experts top-8.

32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    pattern=("moe",),
    rope_theta=10000.0,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
