from .base import (
    EncDecConfig,
    ModelConfig,
    MoEConfig,
    SHAPES,
    SMOKE_SHAPE,
    ShapeConfig,
    SSMConfig,
    VLMConfig,
    reduced,
)
from .registry import ARCHS, cell_is_applicable, get_config, get_shape, list_archs

__all__ = [
    "EncDecConfig", "ModelConfig", "MoEConfig", "SHAPES", "SMOKE_SHAPE",
    "ShapeConfig", "SSMConfig", "VLMConfig", "reduced",
    "ARCHS", "cell_is_applicable", "get_config", "get_shape", "list_archs",
]
