"""gemma3-1b [dense] — 5:1 local:global attention, 128k context.

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144
[hf:google/gemma-3-1b-pt; unverified]
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab_size=262144,
    head_dim=256,
    pattern=("swa",) * 5 + ("global",),
    sliding_window=512,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)
