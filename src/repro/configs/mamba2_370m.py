"""mamba2-370m [ssm] — attention-free, SSD (state-space duality).

48L d_model=1024 d_ff=0 vocab=50280 ssm_state=128
[arXiv:2405.21060; unverified]
"""

from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,  # SSD heads: d_inner(2048) / head_dim(64)
    n_kv_heads=32,
    d_ff=0,
    vocab_size=50280,
    head_dim=64,
    pattern=("mamba",),
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1),
    source="arXiv:2405.21060; unverified",
)
