"""Shared pure-JAX layer primitives (no flax — params are plain pytrees).

Conventions:
  * params are nested dicts of jnp arrays; every layer has ``init_*`` and a
    pure apply function;
  * activations compute in ``cfg.compute_dtype`` (bf16 by default), softmax
    and norm statistics in fp32;
  * init functions are cheap and `jax.eval_shape`-safe (dry-runs never
    materialize full-size weights).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def dt(name: str):
    return jnp.dtype(name)


# ------------------------------------------------------------------- norms
def init_rmsnorm(d: int, dtype) -> Dict:
    return {"scale": jnp.zeros((d,), dtype=dtype)}


def rms_norm(x: jnp.ndarray, params: Dict, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with (1+scale) parameterization (gemma-style, zero-init)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    out = normed * (1.0 + params["scale"].astype(jnp.float32))
    return out.astype(x.dtype)


# -------------------------------------------------------------------- rope
def rope_frequencies(
    head_dim: int, positions: jnp.ndarray, theta: float
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions: [...]; returns cos/sin of shape [..., head_dim//2]."""
    half = head_dim // 2
    freq = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )
    angles = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """x: [B, S, H, D]; positions: [B, S] (or [S])."""
    b, s, h, d = x.shape
    cos, sin = rope_frequencies(d, positions, theta)  # [B, S, D/2]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ----------------------------------------------------------------- linear
def init_dense(
    rng, d_in: int, d_out: int, dtype, scale: Optional[float] = None
) -> Dict:
    scale = scale if scale is not None else d_in**-0.5
    w = jax.random.normal(rng, (d_in, d_out), dtype=jnp.float32) * scale
    return {"w": w.astype(dtype)}


def dense(x: jnp.ndarray, params: Dict) -> jnp.ndarray:
    return x @ params["w"].astype(x.dtype)


# -------------------------------------------------------------------- mlp
def init_mlp(rng, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    pdt = dt(cfg.param_dtype)
    ks = jax.random.split(rng, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "gate": init_dense(ks[0], d, f, pdt),
            "up": init_dense(ks[1], d, f, pdt),
            "down": init_dense(ks[2], f, d, pdt, scale=f**-0.5),
        }
    return {
        "up": init_dense(ks[0], d, f, pdt),
        "down": init_dense(ks[1], f, d, pdt, scale=f**-0.5),
    }


def mlp(x: jnp.ndarray, params: Dict, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.mlp_type == "swiglu":
        return dense(
            jax.nn.silu(dense(x, params["gate"])) * dense(x, params["up"]),
            params["down"],
        )
    return dense(jax.nn.gelu(dense(x, params["up"])), params["down"])


# -------------------------------------------------------------- embeddings
def padded_vocab(vocab_size: int, multiple: int = 256) -> int:
    """Vocab rows padded for clean sharding (SPMD rejects uneven input
    shardings) and lane alignment.  Padded logit columns are sliced off in
    ``unembed`` so the softmax never sees them."""
    return -(-vocab_size // multiple) * multiple


def init_embedding(rng, cfg: ModelConfig) -> Dict:
    pdt = dt(cfg.param_dtype)
    v_pad = padded_vocab(cfg.vocab_size)
    emb = (
        jax.random.normal(rng, (v_pad, cfg.d_model), dtype=jnp.float32) * 0.02
    )
    out = {"table": emb.astype(pdt)}
    if not cfg.tie_embeddings:
        out["lm_head"] = (
            jax.random.normal(
                jax.random.fold_in(rng, 1),
                (cfg.d_model, v_pad),
                dtype=jnp.float32,
            )
            * cfg.d_model**-0.5
        ).astype(pdt)
    return out


def embed(tokens: jnp.ndarray, params: Dict, cfg: ModelConfig) -> jnp.ndarray:
    x = params["table"].astype(dt(cfg.compute_dtype))[tokens]
    # gemma-style sqrt(d) scaling keeps tied-embedding logits sane
    return x * jnp.asarray(cfg.d_model**0.5, dtype=x.dtype)


def unembed(x: jnp.ndarray, params: Dict, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = params["table"].astype(x.dtype)
        logits = x @ w.T
    else:
        logits = x @ params["lm_head"].astype(x.dtype)
    return logits[..., : cfg.vocab_size]  # drop sharding-pad columns


def chunked_cross_entropy(
    x: jnp.ndarray,  # [B, S, d] final-norm hidden states
    params: Dict,
    cfg: ModelConfig,
    labels: jnp.ndarray,  # [B, S]
    mask: Optional[jnp.ndarray] = None,
    chunk: int = 512,
) -> jnp.ndarray:
    """Cross-entropy without materializing [B, S, V] logits.

    Scans over sequence chunks; each chunk unembeds, reduces to per-token
    NLL, and is rematerialized in the backward pass (jax.checkpoint) — peak
    logits memory drops from S·V to chunk·V.  This is the memory-term fix
    for the big-vocab train cells (gemma's V=262k: 34 GiB → ~0.5 GiB of
    live logits per device)."""
    b, s, d = x.shape
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(
            mask if mask is not None else jnp.ones((b, s), jnp.float32),
            ((0, 0), (0, pad)),
        )
    elif mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    nc = x.shape[1] // c
    xs = x.reshape(b, nc, c, d).swapaxes(0, 1)
    ls = labels.reshape(b, nc, c).swapaxes(0, 1)
    ms = mask.reshape(b, nc, c).swapaxes(0, 1)

    def step(carry, inp):
        nll_sum, cnt = carry
        xc, lc, mc = inp
        logits = unembed(xc, params, cfg).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return (nll_sum + nll.sum(), cnt + mc.sum()), None

    step = jax.checkpoint(step)
    (nll_sum, cnt), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ls, ms),
    )
    return nll_sum / jnp.maximum(cnt, 1.0)


# -------------------------------------------------------------------- loss
def softmax_cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, mask: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """Mean next-token CE in fp32; labels [B, S] of token ids."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
