"""Model registry: one uniform API over every architecture family.

``build_model(cfg)`` returns a :class:`ModelApi` whose members are plain
functions suitable for ``jax.jit`` / ``jax.eval_shape`` — init never
allocates under ``eval_shape``, so dry-runs stay allocation-free.

``batch_spec`` describes the logical model inputs per assignment shape
(train / prefill / decode); the launcher turns these into sharded
``ShapeDtypeStruct``s.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import transformer, whisper


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable[[Any], Dict]
    forward: Callable[..., Tuple[jnp.ndarray, jnp.ndarray]]
    loss_fn: Callable[..., Tuple[jnp.ndarray, Dict]]
    init_cache: Callable[[int, int], Dict]
    decode_step: Callable[..., Tuple[jnp.ndarray, Dict]]
    batch_spec: Callable[[ShapeConfig], Dict[str, Tuple[Tuple[int, ...], Any]]]


def _lm_batch_spec(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    if shape.kind == "decode":
        return {"tokens": ((b, 1), jnp.int32)}
    if cfg.family == "vlm":
        p = cfg.vlm.n_patches
        spec = {
            "tokens": ((b, s - p), jnp.int32),
            "prefix_embeds": ((b, p, cfg.d_model), cdt),
        }
        if shape.kind == "train":
            spec["labels"] = ((b, s - p), jnp.int32)
        return spec
    if cfg.family == "encdec":
        spec = {
            "frames": ((b, cfg.encdec.n_frames, cfg.d_model), cdt),
            "tokens": ((b, s), jnp.int32),
        }
        if shape.kind == "train":
            spec["labels"] = ((b, s), jnp.int32)
        return spec
    spec = {"tokens": ((b, s), jnp.int32)}
    if shape.kind == "train":
        spec["labels"] = ((b, s), jnp.int32)
    return spec


def build_model(
    cfg: ModelConfig,
    ep: int = 1,
    impl: str = "ref",
    ep_axis: Optional[str] = None,
) -> ModelApi:
    if cfg.family == "encdec":
        return ModelApi(
            cfg=cfg,
            init=functools.partial(whisper.init_encdec, cfg=cfg, ep=ep),
            forward=functools.partial(whisper.forward, cfg=cfg, impl=impl),
            loss_fn=functools.partial(
                whisper.loss_fn, cfg=cfg, impl=impl, ep_axis=ep_axis
            ),
            init_cache=functools.partial(whisper.init_encdec_cache, cfg),
            decode_step=functools.partial(
                whisper.decode_step, cfg=cfg, impl=impl, ep_axis=ep_axis
            ),
            batch_spec=functools.partial(_lm_batch_spec, cfg),
        )
    return ModelApi(
        cfg=cfg,
        init=functools.partial(transformer.init_lm, cfg=cfg, ep=ep),
        forward=functools.partial(
            transformer.forward, cfg=cfg, impl=impl, ep_axis=ep_axis
        ),
        loss_fn=functools.partial(
            transformer.loss_fn, cfg=cfg, impl=impl, ep_axis=ep_axis
        ),
        init_cache=functools.partial(transformer.init_lm_cache, cfg),
        decode_step=functools.partial(
            transformer.decode_step, cfg=cfg, impl=impl, ep_axis=ep_axis
        ),
        batch_spec=functools.partial(_lm_batch_spec, cfg),
    )


def make_fake_batch(
    cfg: ModelConfig, shape: ShapeConfig, rng: Optional[Any] = None
) -> Dict[str, jnp.ndarray]:
    """Materialize a random batch matching ``batch_spec`` (smoke tests)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    spec = _lm_batch_spec(cfg, shape)
    out = {}
    for i, (name, (shp, dtype)) in enumerate(sorted(spec.items())):
        k = jax.random.fold_in(rng, i)
        if jnp.issubdtype(dtype, jnp.integer):
            out[name] = jax.random.randint(k, shp, 0, cfg.vocab_size, dtype=dtype)
        else:
            out[name] = jax.random.normal(k, shp, dtype=jnp.float32).astype(dtype) * 0.02
    return out
