"""Mixture-of-Experts MLP with top-k routing, capacity-based token dropping,
and explicit expert parallelism.

Two execution paths with identical routing math:

  * **local** — gather/scatter dispatch on one device (smoke tests, decode,
    and the per-device body of the EP path).  Dispatch is sort-based (no
    [T, E, C] one-hot einsums — those inflate HLO FLOPs by orders of
    magnitude and would poison the roofline's MODEL_FLOPS/HLO_FLOPs ratio).
  * **expert-parallel** — ``jax.shard_map`` over the (data, model) mesh:
    tokens are locally dispatched into per-expert capacity buffers, an
    all-to-all over the *model* axis moves them to their expert's shard,
    expert FFNs run as blocked einsums, and a reverse all-to-all brings
    results home.  This is the production EP pattern; the all-to-all bytes
    are visible in the dry-run HLO and accounted in the collective roofline
    term.

Experts whose count does not divide the model-axis size are padded with
never-routed dummy experts (router logits pinned to -inf).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dt, init_dense


def _moe(cfg: ModelConfig):
    assert cfg.moe is not None, f"{cfg.name} has no MoE config"
    return cfg.moe


def padded_experts(cfg: ModelConfig, ep: int) -> int:
    e = _moe(cfg).n_experts
    return e if e % ep == 0 else e + (ep - e % ep)


# ----------------------------------------------------------------- params
def init_moe(rng, cfg: ModelConfig, ep: int = 1) -> Dict:
    m = _moe(cfg)
    d, f = cfg.d_model, m.d_ff_expert
    e_pad = padded_experts(cfg, ep)
    pdt = dt(cfg.param_dtype)
    ks = jax.random.split(rng, 4)

    def expert_mat(key, d_in, d_out):
        w = (
            jax.random.normal(key, (e_pad, d_in, d_out), dtype=jnp.float32)
            * d_in**-0.5
        )
        return w.astype(pdt)

    return {
        "router": init_dense(ks[0], d, m.n_experts, jnp.float32),
        "gate": expert_mat(ks[1], d, f),
        "up": expert_mat(ks[2], d, f),
        "down": expert_mat(ks[3], f, d),
    }


# ---------------------------------------------------------- local dispatch
def _route(
    x_flat: jnp.ndarray, params: Dict, cfg: ModelConfig
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Router: top-k gates (renormalized) + aux load-balance loss terms."""
    m = _moe(cfg)
    logits = (x_flat.astype(jnp.float32) @ params["router"]["w"])  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)  # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )
    # Switch-style aux loss: E * Σ_e (token_frac_e · prob_mass_e)
    top1 = expert_idx[:, 0]
    token_frac = jnp.mean(
        jax.nn.one_hot(top1, m.n_experts, dtype=jnp.float32), axis=0
    )
    prob_mass = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(token_frac * prob_mass)
    return gate_vals, expert_idx, aux


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = _moe(cfg)
    c = int(m.capacity_factor * n_tokens * m.top_k / m.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def _dispatch_tables(
    expert_idx: jnp.ndarray,  # [T, k]
    n_tokens: int,
    e_pad: int,
    cap: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based slot assignment.

    Returns (slot_table [e_pad*cap] of token ids (n_tokens == empty),
             token_slots [T, k] of slot ids (e_pad*cap == dropped))."""
    t, k = expert_idx.shape
    eflat = expert_idx.reshape(-1)  # [T*k]
    order = jnp.argsort(eflat, stable=True)  # token-priority within expert
    sorted_e = eflat[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e_pad))  # [e_pad]
    pos = jnp.arange(t * k) - starts[sorted_e]
    keep = pos < cap
    slot_sorted = jnp.where(keep, sorted_e * cap + pos, e_pad * cap)
    token_sorted = order // k
    slot_table = jnp.full((e_pad * cap + 1,), t, dtype=jnp.int32)
    slot_table = slot_table.at[slot_sorted].set(
        token_sorted.astype(jnp.int32), mode="drop"
    )[:-1]
    token_slots = (
        jnp.zeros((t * k,), dtype=jnp.int32)
        .at[order]
        .set(slot_sorted.astype(jnp.int32))
        .reshape(t, k)
    )
    return slot_table, token_slots


def _expert_ffn(expert_in: jnp.ndarray, params: Dict, cfg: ModelConfig):
    """expert_in: [E?, C?, d] blocked einsum FFN (SwiGLU)."""
    cdt = dt(cfg.compute_dtype)
    g = jnp.einsum("ecd,edf->ecf", expert_in, params["gate"].astype(cdt))
    u = jnp.einsum("ecd,edf->ecf", expert_in, params["up"].astype(cdt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(cdt) * u
    return jnp.einsum("ecf,efd->ecd", h, params["down"].astype(cdt))


def moe_mlp_local(
    params: Dict, x: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-device MoE (also the EP per-shard body without collectives).

    x: [B, S, d] → ([B, S, d], aux loss scalar)."""
    b, s, d = x.shape
    t = b * s
    e_pad = params["gate"].shape[0]
    x_flat = x.reshape(t, d)
    gates, expert_idx, aux = _route(x_flat, params, cfg)
    cap = _capacity(t, cfg)
    slot_table, token_slots = _dispatch_tables(expert_idx, t, e_pad, cap)
    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, d), dtype=x.dtype)])
    expert_in = x_pad[slot_table].reshape(e_pad, cap, d)
    expert_out = _expert_ffn(expert_in, params, cfg)
    out_pad = jnp.concatenate(
        [expert_out.reshape(e_pad * cap, d), jnp.zeros((1, d), dtype=x.dtype)]
    )
    y = (out_pad[token_slots] * gates[..., None].astype(x.dtype)).sum(axis=1)
    return y.reshape(b, s, d), aux


# ------------------------------------------------------- expert parallelism
def moe_mlp_ep(
    params: Dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    model_axis: str = "model",
    reduce_axes: Tuple[str, ...] = ("data", "model"),
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """shard_map body: x is the LOCAL shard [b_l, s_l, d]; experts are
    sharded over ``model_axis``.  Performs dispatch-all_to_all-ffn-return."""
    b, s, d = x.shape
    t = b * s
    ep = jax.lax.axis_size(model_axis)
    e_pad = params["gate"].shape[0]  # local view: params sharded outside
    e_pad_global = e_pad * ep
    x_flat = x.reshape(t, d)
    # Router weights are replicated; routing happens where the tokens live.
    gates, expert_idx, aux = _route(x_flat, params, cfg)
    cap = _capacity(t, cfg)
    slot_table, token_slots = _dispatch_tables(
        expert_idx, t, e_pad_global, cap
    )
    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, d), dtype=x.dtype)])
    send = x_pad[slot_table].reshape(e_pad_global, cap, d)
    # all-to-all over the model axis: [E_glob, C, d] → [E_loc, P*C, d]
    recv = jax.lax.all_to_all(
        send, model_axis, split_axis=0, concat_axis=1, tiled=True
    )
    expert_out = _expert_ffn(recv, params, cfg)
    # reverse exchange: [E_loc, P*C, d] → [E_glob, C, d]
    back = jax.lax.all_to_all(
        expert_out, model_axis, split_axis=1, concat_axis=0, tiled=True
    )
    out_pad = jnp.concatenate(
        [back.reshape(e_pad_global * cap, d), jnp.zeros((1, d), dtype=x.dtype)]
    )
    y = (out_pad[token_slots] * gates[..., None].astype(x.dtype)).sum(axis=1)
    aux = jax.lax.pmean(aux, reduce_axes)
    return y.reshape(b, s, d), aux
