"""Attention: GQA with causal / sliding-window / bidirectional / cross modes,
prefill and single-token decode paths.

The jnp implementation here is the *reference semantics*; the Pallas
flash-attention kernels in ``repro.kernels`` implement the same math with
VMEM tiling and are validated against this module (tests sweep shapes &
dtypes).  Model code selects the implementation via ``impl=`` — dry-runs use
"ref" (XLA fuses it; keeps HLO compact at 512 devices), TPU runs would use
"flash".
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import apply_rope, dense, dt, init_dense

NEG_INF = -2.0**30


# ---------------------------------------------------------------- params
def init_attention(rng, cfg: ModelConfig) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim_
    pdt = dt(cfg.param_dtype)
    ks = jax.random.split(rng, 4)
    return {
        "q": init_dense(ks[0], d, cfg.n_heads * hd, pdt),
        "k": init_dense(ks[1], d, cfg.n_kv_heads * hd, pdt),
        "v": init_dense(ks[2], d, cfg.n_kv_heads * hd, pdt),
        "o": init_dense(ks[3], cfg.n_heads * hd, d, pdt),
    }


# ------------------------------------------------------------- core math
def gqa_attention(
    q: jnp.ndarray,  # [B, Sq, Hq, D]
    k: jnp.ndarray,  # [B, Sk, Hkv, D]
    v: jnp.ndarray,  # [B, Sk, Hkv, D]
    positions_q: jnp.ndarray,  # [B, Sq]
    positions_k: jnp.ndarray,  # [B, Sk]
    causal: bool = True,
    window: Optional[int] = None,
    kv_valid: Optional[jnp.ndarray] = None,  # [B, Sk] bool
) -> jnp.ndarray:
    """Grouped-query attention with fp32 softmax; returns [B, Sq, Hq, D]."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    q = q.reshape(b, sq, hkv, g, d)
    scale = d**-0.5
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    mask = jnp.ones((b, sq, sk), dtype=bool)
    dpos = positions_q[:, :, None] - positions_k[:, None, :]
    if causal:
        mask &= dpos >= 0
    if window is not None:
        mask &= dpos < window
    if kv_valid is not None:
        mask &= kv_valid[:, None, :]
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hq, d)


# ------------------------------------------------------------ block apply
def attention_block(
    params: Dict,
    x: jnp.ndarray,  # [B, S, d_model]
    positions: jnp.ndarray,  # [B, S]
    cfg: ModelConfig,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    rope_theta: Optional[float] = None,
    cache: Optional[Dict] = None,
    cache_index: Optional[jnp.ndarray] = None,
    impl: str = "ref",
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full attention sub-block: qkv proj → rope → attention → out proj.

    With ``cache``/``cache_index``: single-token decode — x is [B, 1, d],
    the KV cache is updated in place (functionally) at ``cache_index``.
    """
    b, s, _ = x.shape
    hd = cfg.head_dim_
    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    q = dense(x, params["q"]).reshape(b, s, cfg.n_heads, hd)
    k = dense(x, params["k"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = dense(x, params["v"]).reshape(b, s, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)

    new_cache = None
    if cache is not None:
        assert cache_index is not None
        # decode: write k/v at cache_index (ring buffer — SWA caches are
        # allocated at window length, so the write index wraps; full-length
        # caches hit the identity case of the same formula)
        s_cache = cache["k"].shape[1]
        write_idx = cache_index % s_cache
        quantized = cache["k"].dtype == jnp.int8
        if quantized:
            # per-token-per-head symmetric int8 (scales stored alongside)
            def q8(t):
                scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) / 127.0
                scale = jnp.maximum(scale, 1e-8)
                q = jnp.clip(
                    jnp.round(t.astype(jnp.float32) / scale[..., None]),
                    -127, 127,
                ).astype(jnp.int8)
                return q, scale

            k8, k_s = q8(k)
            v8, v_s = q8(v)
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k8, write_idx, axis=1
            )
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v8, write_idx, axis=1
            )
            cks = jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], k_s.astype(cache["k_scale"].dtype), write_idx, axis=1
            )
            cvs = jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], v_s.astype(cache["v_scale"].dtype), write_idx, axis=1
            )
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
            cdt = dt(cfg.compute_dtype)
            ck = (ck.astype(jnp.float32) * cks.astype(jnp.float32)[..., None]).astype(cdt)
            cv = (cv.astype(jnp.float32) * cvs.astype(jnp.float32)[..., None]).astype(cdt)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), write_idx, axis=1
            )
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), write_idx, axis=1
            )
            new_cache = {"k": ck, "v": cv}
        sk = ck.shape[1]
        # slot j holds absolute position pos - ((pos - j) mod s_cache);
        # never-written slots resolve to negative positions → masked.
        slots = jnp.arange(sk)[None, :]
        pos_now = cache_index + s - 1
        positions_k = pos_now - jnp.mod(pos_now - slots, s_cache)
        positions_k = jnp.broadcast_to(positions_k, (b, sk)).astype(jnp.int32)
        kv_valid = positions_k >= 0
        if impl == "flash" and s == 1:
            from ..kernels.decode_attention import ops as dec_ops

            out = dec_ops.decode_attention(
                q, ck, cv, positions[:, 0], window=window
            )
        else:
            out = gqa_attention(
                q,
                ck,
                cv,
                positions,
                positions_k,
                causal=causal,
                window=window,
                kv_valid=kv_valid,
            )
    else:
        if impl == "flash":
            from ..kernels.flash_attention import ops as fa_ops

            out = fa_ops.flash_attention(
                q, k, v, causal=causal, window=window
            )
        elif impl == "blocked":
            from .blocked_attention import blocked_attention

            out = blocked_attention(
                q, k, v, positions, positions, causal, window, 1024, False
            )
        else:
            out = gqa_attention(
                q, k, v, positions, positions, causal=causal, window=window
            )
    out = dense(out.reshape(b, s, cfg.n_heads * hd), params["o"])
    return out, new_cache


def cross_attention_block(
    params: Dict,
    x: jnp.ndarray,  # [B, Sq, d]
    enc_kv: Tuple[jnp.ndarray, jnp.ndarray],  # precomputed K,V [B, Sk, Hkv, D]
    cfg: ModelConfig,
    impl: str = "ref",
) -> jnp.ndarray:
    """Encoder-decoder cross attention (whisper); enc K/V precomputed once."""
    b, s, _ = x.shape
    hd = cfg.head_dim_
    q = dense(x, params["q"]).reshape(b, s, cfg.n_heads, hd)
    k, v = enc_kv
    sk = k.shape[1]
    pos_q = jnp.zeros((b, s), dtype=jnp.int32)
    pos_k = jnp.zeros((b, sk), dtype=jnp.int32)
    if impl == "blocked" and s > 1:
        from .blocked_attention import blocked_attention

        out = blocked_attention(q, k, v, pos_q, pos_k, False, None, 1024, False)
    else:
        out = gqa_attention(q, k, v, pos_q, pos_k, causal=False, window=None)
    return dense(out.reshape(b, s, cfg.n_heads * hd), params["o"])


def precompute_cross_kv(
    params: Dict, enc_out: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, sk, _ = enc_out.shape
    hd = cfg.head_dim_
    k = dense(enc_out, params["k"]).reshape(b, sk, cfg.n_kv_heads, hd)
    v = dense(enc_out, params["v"]).reshape(b, sk, cfg.n_kv_heads, hd)
    return k, v


def init_kv_cache(
    cfg: ModelConfig, batch: int, max_len: int, n_layers: int, dtype=None
) -> Dict:
    """Per-layer KV cache pytree: leaves [L, B, max_len, Hkv, D]."""
    dtype = dtype or dt(cfg.compute_dtype)
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
    return {"k": jnp.zeros(shape, dtype=dtype), "v": jnp.zeros(shape, dtype=dtype)}
