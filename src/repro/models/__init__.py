from .registry import ModelApi, build_model, make_fake_batch

__all__ = ["ModelApi", "build_model", "make_fake_batch"]
