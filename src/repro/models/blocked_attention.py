"""Flash-style blocked attention in pure JAX (XLA-compilable anywhere).

The Pallas kernel (repro.kernels.flash_attention) is the TPU hot path; this
module is the same algorithm expressed as a ``lax.scan`` over KV tiles with
a custom VJP, so that

  * dry-runs (CPU host platform, 512 fake devices) lower a program whose
    peak memory matches the kernelized TPU program — no S×S score buffer is
    ever live (the baseline jnp reference materializes it; that is what
    made every prefill/train cell memory-bound in the baseline table);
  * the backward pass uses the flash recomputation trick (save only
    (q, k, v, out, lse); rebuild P per tile), instead of lax.scan's default
    save-everything autodiff, which would re-introduce O(S²) residuals;
  * under GSPMD + sequence parallelism the per-tile K/V gathers become the
    standard SP attention schedule (per-block all-gather on the ICI).

Semantics (causal / sliding-window / GQA) are validated against
``attention.gqa_attention`` and the Pallas kernel in tests.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0**30


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _mask(
    pos_q: jnp.ndarray,  # [B, Sq]
    pos_k: jnp.ndarray,  # [B, bk]
    causal: bool,
    window: Optional[int],
    kv_valid: Optional[jnp.ndarray],  # [B, bk]
) -> jnp.ndarray:
    dpos = pos_q[:, :, None] - pos_k[:, None, :]
    m = jnp.ones(dpos.shape, dtype=bool)
    if causal:
        m &= dpos >= 0
    if window is not None:
        m &= dpos < window
    if kv_valid is not None:
        m &= kv_valid[:, None, :]
    return m  # [B, Sq, bk]


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8)
)
def blocked_attention(
    q: jnp.ndarray,  # [B, Sq, Hq, D]
    k: jnp.ndarray,  # [B, Sk, Hkv, D]
    v: jnp.ndarray,  # [B, Sk, Hkv, D]
    positions_q: jnp.ndarray,  # [B, Sq]
    positions_k: jnp.ndarray,  # [B, Sk]
    causal: bool = True,
    window: Optional[int] = None,
    block_k: int = 1024,
    kv_valid_static: bool = False,  # reserved; decode uses the Pallas path
) -> jnp.ndarray:
    out, _ = _fwd_impl(q, k, v, positions_q, positions_k, causal, window, block_k)
    return out


def _fwd_impl(q, k, v, positions_q, positions_k, causal, window, block_k):
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = d**-0.5
    bk = min(block_k, sk)
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    pkp = _pad_to(positions_k, 1, bk)
    validp = _pad_to(jnp.ones((b, sk), dtype=bool), 1, bk)
    nk = kp.shape[1] // bk
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)

    def tiles(x):
        return x.reshape(b, nk, bk, *x.shape[2:]).swapaxes(0, 1)

    kt, vt, pkt, vt_valid = tiles(kp), tiles(vp), tiles(pkp), tiles(validp)

    def step(carry, xs):
        m_run, l_run, acc = carry
        kb, vb, pkb, valb = xs
        s = (
            jnp.einsum(
                "bqhgd,bkhd->bhgqk", qf, kb.astype(jnp.float32)
            )
            * scale
        )
        msk = _mask(positions_q, pkb, causal, window, valb)
        s = jnp.where(msk[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32),
        jnp.zeros((b, hkv, g, sq), jnp.float32),
        jnp.zeros((b, hkv, g, sq, d), jnp.float32),
    )
    (m_run, l_run, acc), _ = jax.lax.scan(step, init, (kt, vt, pkt, vt_valid))
    l_safe = jnp.maximum(l_run, 1e-30)
    # [B, Hkv, G, Sq, D] → [B, Sq, Hkv, G, D] → [B, Sq, Hq, D]
    out = (acc / l_safe[..., None]).transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)
    lse = m_run + jnp.log(l_safe)  # [B, Hkv, G, Sq]
    return out.astype(q.dtype), lse


def _fwd_rule(
    q, k, v, positions_q, positions_k, causal, window, block_k, kv_valid_static
):
    out, lse = _fwd_impl(
        q, k, v, positions_q, positions_k, causal, window, block_k
    )
    return out, (q, k, v, out, lse, positions_q, positions_k)


def _bwd_rule(causal, window, block_k, _kv_valid_static, residuals, dout):
    q, k, v, out, lse, positions_q, positions_k = residuals
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = d**-0.5
    bk = min(block_k, sk)
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    pkp = _pad_to(positions_k, 1, bk)
    validp = _pad_to(jnp.ones((b, sk), dtype=bool), 1, bk)
    nk = kp.shape[1] // bk
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    dof = dout.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    of = out.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    # D_i = Σ_d dout⊙out  (flash backward identity)
    delta = jnp.einsum("bqhgd,bqhgd->bhgq", dof, of)

    def tiles(x):
        return x.reshape(b, nk, bk, *x.shape[2:]).swapaxes(0, 1)

    kt, vt, pkt, valt = tiles(kp), tiles(vp), tiles(pkp), tiles(validp)

    def step(dq_acc, xs):
        kb, vb, pkb, valb = xs
        s = (
            jnp.einsum("bqhgd,bkhd->bhgqk", qf, kb.astype(jnp.float32))
            * scale
        )
        msk = _mask(positions_q, pkb, causal, window, valb)
        s = jnp.where(msk[:, None, None, :, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])  # [B,Hkv,G,Sq,bk]
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", dof, vb.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum(
            "bhgqk,bkhd->bqhgd", ds, kb.astype(jnp.float32)
        )
        dk_b = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qf)
        dv_b = jnp.einsum("bhgqk,bqhgd->bkhd", p, dof)
        return dq_acc, (dk_b, dv_b)

    dq0 = jnp.zeros((b, sq, hkv, g, d), jnp.float32)
    dq, (dk_t, dv_t) = jax.lax.scan(step, dq0, (kt, vt, pkt, valt))
    dk = dk_t.swapaxes(0, 1).reshape(b, nk * bk, hkv, d)[:, :sk]
    dv = dv_t.swapaxes(0, 1).reshape(b, nk * bk, hkv, d)[:, :sk]
    return (
        dq.reshape(b, sq, hq, d).astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
        None,
        None,
    )


blocked_attention.defvjp(_fwd_rule, _bwd_rule)
