"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings [B, n_frames, d_model].  The backbone —
bidirectional encoder, causal decoder with per-layer cross-attention — is
implemented fully, with both stacks scanned over layers.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import attention_block, cross_attention_block, init_attention, precompute_cross_kv
from .layers import dt, embed, init_embedding, init_mlp, init_rmsnorm, mlp, rms_norm, unembed


def _enc(cfg: ModelConfig):
    assert cfg.encdec is not None, f"{cfg.name} is not enc-dec"
    return cfg.encdec


# ------------------------------------------------------------------- init
def _init_enc_layer(rng, cfg: ModelConfig) -> Dict:
    ks = jax.random.split(rng, 2)
    pdt = dt(cfg.param_dtype)
    return {
        "ln1": init_rmsnorm(cfg.d_model, pdt),
        "attn": init_attention(ks[0], cfg),
        "ln2": init_rmsnorm(cfg.d_model, pdt),
        "mlp": init_mlp(ks[1], cfg),
    }


def _init_dec_layer(rng, cfg: ModelConfig) -> Dict:
    ks = jax.random.split(rng, 3)
    pdt = dt(cfg.param_dtype)
    return {
        "ln1": init_rmsnorm(cfg.d_model, pdt),
        "self_attn": init_attention(ks[0], cfg),
        "ln_x": init_rmsnorm(cfg.d_model, pdt),
        "cross_attn": init_attention(ks[1], cfg),
        "ln2": init_rmsnorm(cfg.d_model, pdt),
        "mlp": init_mlp(ks[2], cfg),
    }


def init_encdec(rng, cfg: ModelConfig, ep: int = 1) -> Dict:
    e = _enc(cfg)
    enc_keys = jax.random.split(jax.random.fold_in(rng, 1), e.n_enc_layers)
    dec_keys = jax.random.split(jax.random.fold_in(rng, 2), cfg.n_layers)
    pdt = dt(cfg.param_dtype)
    return {
        "embed": init_embedding(jax.random.fold_in(rng, 0), cfg),
        "encoder": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": init_rmsnorm(cfg.d_model, pdt),
        "decoder": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "final_norm": init_rmsnorm(cfg.d_model, pdt),
    }


# ---------------------------------------------------------------- encoder
def encode(
    params: Dict, frames: jnp.ndarray, cfg: ModelConfig, remat: bool = False,
    impl: str = "ref",
) -> jnp.ndarray:
    """frames: [B, F, d_model] (stub frontend output) → enc states."""
    from ..distributed.context import constrain

    b, f, _ = frames.shape
    x = constrain(frames.astype(dt(cfg.compute_dtype)), "batch")
    positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32)[None], (b, f))

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        out, _ = attention_block(
            lp["attn"], h, positions, cfg, causal=False, window=None, impl=impl
        )
        x = x + out
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + mlp(h, lp["mlp"], cfg), None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------- decoder
def forward(
    params: Dict,
    frames: jnp.ndarray,  # [B, F, d_model]
    tokens: jnp.ndarray,  # [B, S]
    cfg: ModelConfig,
    impl: str = "ref",
    remat: bool = False,
    last_only: bool = False,
    return_hidden: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Teacher-forced enc-dec forward → (logits, aux=0)."""
    from ..distributed.context import constrain

    enc_out = encode(params, frames, cfg, remat=remat, impl=impl)
    x = constrain(embed(tokens, params["embed"], cfg), "residual")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        out, _ = attention_block(
            lp["self_attn"], h, positions, cfg, causal=True, impl=impl
        )
        x = x + out
        h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        kv = precompute_cross_kv(lp["cross_attn"], enc_out, cfg)
        x = x + cross_attention_block(lp["cross_attn"], h, kv, cfg, impl=impl)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return constrain(x + mlp(h, lp["mlp"], cfg), "residual"), None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, _ = jax.lax.scan(body, x, params["decoder"])
    if last_only:
        x = x[:, -1:, :]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, jnp.zeros((), jnp.float32)
    logits = constrain(unembed(x, params["embed"], cfg), "logits")
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(
    params: Dict,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    impl: str = "ref",
    ep_axis: Optional[str] = None,
    remat: bool = True,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    hidden, _ = forward(
        params, batch["frames"], batch["tokens"], cfg, impl=impl, remat=remat,
        return_hidden=True,
    )
    from .layers import chunked_cross_entropy

    ce = chunked_cross_entropy(
        hidden, params["embed"], cfg, batch["labels"], batch.get("loss_mask")
    )
    return ce, {"ce": ce, "aux": jnp.zeros(()), "loss": ce}


# ----------------------------------------------------------------- decode
def init_encdec_cache(
    cfg: ModelConfig, batch: int, max_len: int
) -> Dict[str, Any]:
    e = _enc(cfg)
    cdt = dt(cfg.compute_dtype)
    l = cfg.n_layers
    kv = (l, batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
    xkv = (l, batch, e.n_frames, cfg.n_kv_heads, cfg.head_dim_)
    return {
        "self": {"k": jnp.zeros(kv, cdt), "v": jnp.zeros(kv, cdt)},
        "cross": {"k": jnp.zeros(xkv, cdt), "v": jnp.zeros(xkv, cdt)},
    }


def prefill_cross_cache(
    params: Dict, frames: jnp.ndarray, cache: Dict, cfg: ModelConfig
) -> Dict:
    """Fill the cross-attention KV from encoder output (once per request)."""
    enc_out = encode(params, frames, cfg)

    def body(_, lp):
        return None, jnp.stack(precompute_cross_kv(lp["cross_attn"], enc_out, cfg))

    _, kvs = jax.lax.scan(body, None, params["decoder"])  # [L, 2, B, F, H, D]
    return {
        "self": cache["self"],
        "cross": {"k": kvs[:, 0], "v": kvs[:, 1]},
    }


def decode_step(
    params: Dict,
    cache: Dict,
    tokens: jnp.ndarray,  # [B, 1]
    pos_index: jnp.ndarray,
    cfg: ModelConfig,
    impl: str = "ref",
    ep_axis: Optional[str] = None,
) -> Tuple[jnp.ndarray, Dict]:
    x = embed(tokens, params["embed"], cfg)
    b = x.shape[0]
    positions = jnp.broadcast_to(
        pos_index.astype(jnp.int32)[None, None], (b, 1)
    )

    def body(x, xs):
        lp, kc, vc, xk, xv = xs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        out, new_kv = attention_block(
            lp["self_attn"],
            h,
            positions,
            cfg,
            causal=True,
            cache={"k": kc, "v": vc},
            cache_index=pos_index,
            impl=impl,
        )
        x = x + out
        h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        x = x + cross_attention_block(lp["cross_attn"], h, (xk, xv), cfg)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp(h, lp["mlp"], cfg)
        return x, (new_kv["k"], new_kv["v"])

    x, (nk, nv) = jax.lax.scan(
        body,
        x,
        (
            params["decoder"],
            cache["self"]["k"],
            cache["self"]["v"],
            cache["cross"]["k"],
            cache["cross"]["v"],
        ),
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"], cfg)
    return logits, {"self": {"k": nk, "v": nv}, "cross": cache["cross"]}
