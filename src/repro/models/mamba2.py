"""Mamba2 (SSD — state-space duality) block in pure JAX.

Implements the chunked SSD algorithm of arXiv:2405.21060: within a chunk the
computation is a masked (decay-weighted) attention-like quadratic form; the
state is carried across chunks with a linear recurrence.  This module is the
*reference semantics*; ``repro.kernels.ssd_scan`` provides the Pallas
TPU kernel for the intra-chunk part, validated against :func:`ssd_chunked`.

Decode is O(1) per token: a [B, H, P, N] state and a small conv cache.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dt, init_dense, rms_norm


def _ssm(cfg: ModelConfig):
    assert cfg.ssm is not None, f"{cfg.name} has no SSM config"
    return cfg.ssm


def mamba_dims(cfg: ModelConfig) -> Dict[str, int]:
    s = _ssm(cfg)
    d_in = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return {
        "d_inner": d_in,
        "n_heads": nh,
        "head_dim": s.head_dim,
        "d_state": s.d_state,
        "n_groups": s.n_groups,
        "conv_ch": conv_ch,
        "conv_width": s.conv_width,
        "in_dim": 2 * d_in + 2 * s.n_groups * s.d_state + nh,
    }


# ----------------------------------------------------------------- params
#
# NOTE on layout: the reference Mamba2 fuses z/x/B/C/dt into one in_proj and
# one depthwise conv.  We keep them as SEPARATE matrices: mathematically
# identical (depthwise conv and matmul both act per-channel/column), but the
# split projections shard cleanly under tensor parallelism — z/x/dt are
# column-parallel over heads, B/C stay replicated (tiny), out_proj is
# row-parallel.  The fused layout would straddle TP shard boundaries.
def init_mamba_block(rng, cfg: ModelConfig) -> Dict:
    dims = mamba_dims(cfg)
    pdt = dt(cfg.param_dtype)
    ks = jax.random.split(rng, 6)
    w = dims["conv_width"]
    gn2 = 2 * dims["n_groups"] * dims["d_state"]

    def conv_init(key, ch):
        return (
            jax.random.normal(key, (w, ch), jnp.float32) * w**-0.5
        ).astype(pdt)

    return {
        "z_proj": init_dense(ks[0], cfg.d_model, dims["d_inner"], pdt),
        "x_proj": init_dense(ks[1], cfg.d_model, dims["d_inner"], pdt),
        "bc_proj": init_dense(ks[2], cfg.d_model, gn2, pdt),
        "dt_proj": init_dense(ks[3], cfg.d_model, dims["n_heads"], pdt),
        "conv_x_w": conv_init(ks[4], dims["d_inner"]),
        "conv_x_b": jnp.zeros((dims["d_inner"],), dtype=pdt),
        "conv_bc_w": conv_init(ks[5], gn2),
        "conv_bc_b": jnp.zeros((gn2,), dtype=pdt),
        "A_log": jnp.zeros((dims["n_heads"],), dtype=jnp.float32),
        "D": jnp.ones((dims["n_heads"],), dtype=jnp.float32),
        "dt_bias": jnp.zeros((dims["n_heads"],), dtype=jnp.float32),
        "gate_norm": {"scale": jnp.zeros((dims["d_inner"],), dtype=pdt)},
        "out_proj": init_dense(jax.random.fold_in(ks[3], 7), dims["d_inner"], cfg.d_model, pdt),
    }


# ------------------------------------------------------------ SSD (chunked)
def segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k]
    (lower-triangular; -inf above the diagonal)."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), dtype=bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,  # [B, S, H, P] (already dt-weighted)
    dA: jnp.ndarray,  # [B, S, H]   (dt * A, negative)
    B_: jnp.ndarray,  # [B, S, H, N] (groups already broadcast to heads)
    C_: jnp.ndarray,  # [B, S, H, N]
    chunk: int,
    initial_state: Optional[jnp.ndarray] = None,  # [B, H, P, N]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD; returns (y [B,S,H,P], final_state [B,H,P,N])."""
    b, s, h, p = x.shape
    n = B_.shape[-1]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    c = s // chunk
    xf = x.astype(jnp.float32).reshape(b, c, chunk, h, p)
    dAf = dA.astype(jnp.float32).reshape(b, c, chunk, h)
    Bf = B_.astype(jnp.float32).reshape(b, c, chunk, h, n)
    Cf = C_.astype(jnp.float32).reshape(b, c, chunk, h, n)

    cum = jnp.cumsum(dAf, axis=2)  # [B,C,Q,H]
    # ---- intra-chunk (the "attention-like" diagonal block) ----
    L = jnp.exp(segsum(dAf.transpose(0, 1, 3, 2)))  # [B,C,H,Q,Q]
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cf, Bf) * L
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores, xf)
    # ---- per-chunk final states ----
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)  # [B,C,Q,H]
    chunk_states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bf, decay_states, xf)
    # ---- inter-chunk recurrence ----
    total_decay = jnp.exp(cum[:, :, -1, :])  # [B,C,H]

    def step(state, inp):
        st_c, dec_c = inp  # [B,H,P,N], [B,H]
        new = state * dec_c[:, :, None, None] + st_c
        return new, state  # emit the state *entering* this chunk

    init = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((b, h, p, n), dtype=jnp.float32)
    )
    final_state, prev_states = jax.lax.scan(
        step,
        init,
        (
            chunk_states.transpose(1, 0, 2, 3, 4),  # [C,B,H,P,N]
            total_decay.transpose(1, 0, 2),  # [C,B,H]
        ),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [B,C,H,P,N]
    # ---- contribution of the carried-in state ----
    state_decay = jnp.exp(cum)  # [B,C,Q,H]
    y_off = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", Cf, prev_states, state_decay
    )
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


# ----------------------------------------------------------- block forward
def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """Depthwise causal conv over the sequence dim; xBC [B,S,Ch], w [W,Ch]."""
    width = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for i in range(width):  # W is tiny (4): unrolled taps fuse well
        out = out + pad[:, i : i + xBC.shape[1], :].astype(jnp.float32) * w[
            i
        ].astype(jnp.float32)
    return out + b.astype(jnp.float32)


def mamba_block(
    params: Dict,
    u: jnp.ndarray,  # [B, S, d_model]
    cfg: ModelConfig,
    initial_state: Optional[jnp.ndarray] = None,
    impl: str = "ref",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full Mamba2 mixer; returns (out [B,S,d_model], final_state)."""
    dims = mamba_dims(cfg)
    b, s, _ = u.shape
    h, p, n, g = (
        dims["n_heads"],
        dims["head_dim"],
        dims["d_state"],
        dims["n_groups"],
    )
    z = u @ params["z_proj"]["w"].astype(u.dtype)
    xr = u @ params["x_proj"]["w"].astype(u.dtype)
    bc = u @ params["bc_proj"]["w"].astype(u.dtype)
    dt_raw = u @ params["dt_proj"]["w"].astype(u.dtype)
    xc = jax.nn.silu(_causal_conv(xr, params["conv_x_w"], params["conv_x_b"]))
    bcc = jax.nn.silu(_causal_conv(bc, params["conv_bc_w"], params["conv_bc_b"]))
    x = xc.reshape(b, s, h, p)
    B_ = bcc[..., : g * n].reshape(b, s, g, n)
    C_ = bcc[..., g * n :].reshape(b, s, g, n)
    rep = h // g
    B_h = jnp.repeat(B_, rep, axis=2)
    C_h = jnp.repeat(C_, rep, axis=2)
    dt_ = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :]
    )  # [B,S,H]
    A = -jnp.exp(params["A_log"])  # [H]
    dA = dt_ * A[None, None, :]
    xdt = x.astype(jnp.float32) * dt_[..., None]
    if impl == "ssd_kernel":
        from ..kernels.ssd_scan import ops as ssd_ops

        y, final_state = ssd_ops.ssd(
            xdt, dA, B_h, C_h, chunk=_ssm(cfg).chunk, initial_state=initial_state
        )
    else:
        y, final_state = ssd_chunked(
            xdt, dA, B_h, C_h, chunk=min(_ssm(cfg).chunk, s), initial_state=initial_state
        )
    y = y + params["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, s, dims["d_inner"]).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype),
                 params["gate_norm"], cfg.norm_eps)
    return y @ params["out_proj"]["w"].astype(u.dtype), final_state


# ------------------------------------------------------------------ decode
def init_mamba_cache(cfg: ModelConfig, batch: int, n_layers: int) -> Dict:
    dims = mamba_dims(cfg)
    gn2 = 2 * dims["n_groups"] * dims["d_state"]
    return {
        "ssm": jnp.zeros(
            (n_layers, batch, dims["n_heads"], dims["head_dim"], dims["d_state"]),
            dtype=jnp.float32,
        ),
        "conv_x": jnp.zeros(
            (n_layers, batch, dims["conv_width"] - 1, dims["d_inner"]),
            dtype=jnp.float32,
        ),
        "conv_bc": jnp.zeros(
            (n_layers, batch, dims["conv_width"] - 1, gn2), dtype=jnp.float32
        ),
    }


def mamba_decode_step(
    params: Dict,
    u: jnp.ndarray,  # [B, 1, d_model]
    cache: Dict,  # {"ssm": [B,H,P,N], "conv": [B,W-1,Ch]} — this layer's slice
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict]:
    """O(1) decode: constant-size state, no KV growth (the reason this arch
    family runs the long_500k cell)."""
    dims = mamba_dims(cfg)
    b = u.shape[0]
    h, p, n, g = (
        dims["n_heads"],
        dims["head_dim"],
        dims["d_state"],
        dims["n_groups"],
    )
    u0 = u[:, 0]
    z = u0 @ params["z_proj"]["w"].astype(u.dtype)
    xr = u0 @ params["x_proj"]["w"].astype(u.dtype)
    bc = u0 @ params["bc_proj"]["w"].astype(u.dtype)
    dt_raw = u0 @ params["dt_proj"]["w"].astype(u.dtype)
    # conv caches: window = [cache | new]
    win_x = jnp.concatenate([cache["conv_x"], xr[:, None, :]], axis=1)
    win_bc = jnp.concatenate([cache["conv_bc"], bc[:, None, :]], axis=1)

    def conv1(win, w_, b_):
        return jnp.einsum(
            "bwc,wc->bc", win.astype(jnp.float32), w_.astype(jnp.float32)
        ) + b_.astype(jnp.float32)

    xc = jax.nn.silu(conv1(win_x, params["conv_x_w"], params["conv_x_b"]))
    bcc = jax.nn.silu(conv1(win_bc, params["conv_bc_w"], params["conv_bc_b"]))
    x = xc.reshape(b, h, p)
    B_ = bcc[..., : g * n].reshape(b, g, n)
    C_ = bcc[..., g * n :].reshape(b, g, n)
    rep = h // g
    B_h = jnp.repeat(B_, rep, axis=1)  # [B,H,N]
    C_h = jnp.repeat(C_, rep, axis=1)
    dt_ = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"][None, :]
    )  # [B,H]
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt_ * A[None, :])  # [B,H]
    state = cache["ssm"] * dA[:, :, None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt_, x.astype(jnp.float32), B_h
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, C_h) + params["D"][None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, dims["d_inner"]).astype(u.dtype)
    y = rms_norm(
        (y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype))[:, None, :],
        params["gate_norm"],
        cfg.norm_eps,
    )[:, 0]
    out = (y @ params["out_proj"]["w"].astype(u.dtype))[:, None, :]
    return out, {
        "ssm": state,
        "conv_x": win_x[:, 1:, :],
        "conv_bc": win_bc[:, 1:, :],
    }
