"""Generic pattern-based LM covering the dense / moe / hybrid / ssm / vlm
families.

Depth is organized as ``n_groups`` repetitions of ``cfg.pattern`` (plus an
unrolled tail when depth % pattern ≠ 0) and executed with ``lax.scan`` over
stacked per-group parameters — one pattern body in the HLO regardless of
depth, which keeps 512-device SPMD compiles tractable and is also what makes
per-layer remat policies cheap.

"shared_attn" blocks (zamba2) use ONE parameter set closed over by the scan
body — the weights are shared across occurrences while each occurrence keeps
its own KV cache slice.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import attention_block, init_attention
from .layers import chunked_cross_entropy, dt, embed, init_embedding, init_mlp, init_rmsnorm, mlp, rms_norm, unembed
from .mamba2 import init_mamba_block, mamba_block, mamba_decode_step, mamba_dims
from .moe import init_moe

ATTN_KINDS = ("attn", "global", "swa", "moe", "swa_moe", "shared_attn")


def _kind_window(kind: str, cfg: ModelConfig) -> Optional[int]:
    return cfg.sliding_window if kind in ("swa", "swa_moe") else None


def _kind_theta(kind: str, cfg: ModelConfig) -> float:
    if kind in ("swa", "swa_moe") and cfg.rope_theta_local:
        return cfg.rope_theta_local
    return cfg.rope_theta


# ------------------------------------------------------------------- init
def init_block(rng, kind: str, cfg: ModelConfig, ep: int = 1) -> Dict:
    ks = jax.random.split(rng, 4)
    pdt = dt(cfg.param_dtype)
    if kind == "mamba":
        return {
            "ln1": init_rmsnorm(cfg.d_model, pdt),
            "mamba": init_mamba_block(ks[0], cfg),
        }
    block = {
        "ln1": init_rmsnorm(cfg.d_model, pdt),
        "attn": init_attention(ks[0], cfg),
        "ln2": init_rmsnorm(cfg.d_model, pdt),
    }
    if kind in ("moe", "swa_moe"):
        block["moe"] = init_moe(ks[1], cfg, ep=ep)
    else:
        block["mlp"] = init_mlp(ks[1], cfg)
    return block


def init_lm(rng, cfg: ModelConfig, ep: int = 1) -> Dict:
    pat = cfg.pattern
    g = cfg.n_layers // len(pat)
    tail_kinds = cfg.layer_kinds()[g * len(pat) :]
    params: Dict[str, Any] = {
        "embed": init_embedding(jax.random.fold_in(rng, 0), cfg),
        "final_norm": init_rmsnorm(cfg.d_model, dt(cfg.param_dtype)),
    }
    if g > 0:
        groups = {}
        for i, kind in enumerate(pat):
            if kind == "shared_attn":
                continue  # lives in params["shared"], not per-group
            keys = jax.random.split(jax.random.fold_in(rng, 100 + i), g)
            groups[f"pos{i}"] = jax.vmap(
                lambda k, kd=kind: init_block(k, kd, cfg, ep)
            )(keys)
        params["groups"] = groups
    if "shared_attn" in pat:
        params["shared"] = init_block(
            jax.random.fold_in(rng, 999), "shared_attn", cfg, ep
        )
    if tail_kinds:
        params["tail"] = {
            f"pos{i}": init_block(
                jax.random.fold_in(rng, 200 + i), kind, cfg, ep
            )
            for i, kind in enumerate(tail_kinds)
        }
    return params


# ----------------------------------------------------------------- blocks
def apply_block(
    kind: str,
    bp: Dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    shared: Optional[Dict] = None,
    impl: str = "ref",
    ep_axis: Optional[str] = None,
    cache: Optional[Dict] = None,
    cache_index: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Dict]]:
    """One block; returns (x, aux_loss, new_cache_slice)."""
    aux = jnp.zeros((), dtype=jnp.float32)
    if kind == "shared_attn":
        bp = shared
    if kind == "mamba":
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        if cache is not None:
            out, new_state = mamba_decode_step(bp["mamba"], h, cache, cfg)
            return x + out, aux, new_state
        out, _ = mamba_block(bp["mamba"], h, cfg, impl=impl)
        return x + out, aux, None

    window = _kind_window(kind, cfg)
    theta = _kind_theta(kind, cfg)
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    attn_out, new_cache = attention_block(
        bp["attn"],
        h,
        positions,
        cfg,
        causal=True,
        window=window,
        rope_theta=theta,
        cache=cache,
        cache_index=cache_index,
        impl=impl,
    )
    x = x + attn_out
    h = rms_norm(x, bp["ln2"], cfg.norm_eps)
    if kind in ("moe", "swa_moe"):
        from ..distributed.moe_parallel import moe_maybe_parallel

        ff, aux = moe_maybe_parallel(bp["moe"], h, cfg)
    else:
        ff = mlp(h, bp["mlp"], cfg)
    return x + ff, aux, new_cache


def _apply_pattern(
    x: jnp.ndarray,
    gp: Dict,
    kinds: Tuple[str, ...],
    positions: jnp.ndarray,
    cfg: ModelConfig,
    shared: Optional[Dict],
    impl: str,
    ep_axis: Optional[str],
    caches: Optional[Dict] = None,
    cache_index: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Dict]]:
    aux = jnp.zeros((), dtype=jnp.float32)
    new_caches = {} if caches is not None else None
    for i, kind in enumerate(kinds):
        bp = gp.get(f"pos{i}") if kind != "shared_attn" else None
        cache_i = caches.get(f"pos{i}") if caches is not None else None
        x, a, nc = apply_block(
            kind,
            bp,
            x,
            positions,
            cfg,
            shared=shared,
            impl=impl,
            ep_axis=ep_axis,
            cache=cache_i,
            cache_index=cache_index,
        )
        aux = aux + a
        if new_caches is not None:
            new_caches[f"pos{i}"] = nc
    return x, aux, new_caches


# ---------------------------------------------------------------- forward
def forward(
    params: Dict,
    tokens: jnp.ndarray,  # [B, S_text]
    cfg: ModelConfig,
    prefix_embeds: Optional[jnp.ndarray] = None,  # [B, P, d] (vlm stub)
    impl: str = "ref",
    ep_axis: Optional[str] = None,
    remat: bool = False,
    last_only: bool = False,
    return_hidden: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Teacher-forced forward; returns (logits [B, S_total, V], aux).

    ``last_only`` (prefill): unembed only the final position — avoids
    materializing [B, S, V] logits when only the next token matters.
    ``return_hidden``: skip unembedding, return the final-norm hidden
    states (the chunked-CE loss unembeds per chunk itself)."""
    from ..distributed.context import constrain

    x = embed(tokens, params["embed"], cfg)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = constrain(x, "residual")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    pat = cfg.pattern
    g = cfg.n_layers // len(pat)
    shared = params.get("shared")
    aux_total = jnp.zeros((), dtype=jnp.float32)

    if g > 0:
        def body(carry, gp):
            x, aux = carry
            x, a, _ = _apply_pattern(
                x, gp, pat, positions, cfg, shared, impl, ep_axis
            )
            return (constrain(x, "residual"), aux + a), None

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable
            )
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["groups"])

    tail_kinds = cfg.layer_kinds()[g * len(pat) :]
    if tail_kinds:
        x, a, _ = _apply_pattern(
            x, params["tail"], tuple(tail_kinds), positions, cfg, shared, impl, ep_axis
        )
        aux_total = aux_total + a

    if last_only:
        x = x[:, -1:, :]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, aux_total
    logits = constrain(unembed(x, params["embed"], cfg), "logits")
    return logits, aux_total


def loss_fn(
    params: Dict,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    impl: str = "ref",
    ep_axis: Optional[str] = None,
    remat: bool = True,
    ce_chunk: int = 512,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token CE + MoE aux; batch: tokens/labels [B, S] (+ optional
    prefix_embeds, loss_mask).

    The CE is computed CHUNKED over the sequence (never materializing
    [B, S, V] logits) — with V up to 262k this is the difference between
    fitting HBM and not (EXPERIMENTS.md §Perf)."""
    hidden, aux = forward(
        params,
        batch["tokens"],
        cfg,
        prefix_embeds=batch.get("prefix_embeds"),
        impl=impl,
        ep_axis=ep_axis,
        remat=remat,
        return_hidden=True,
    )
    labels = batch["labels"]
    if hidden.shape[1] != labels.shape[1]:  # vlm: loss only on text positions
        hidden = hidden[:, hidden.shape[1] - labels.shape[1] :]
    ce = chunked_cross_entropy(
        hidden, params["embed"], cfg, labels, batch.get("loss_mask"), ce_chunk
    )
    coef = cfg.moe.router_aux_coef if cfg.moe is not None else 0.0
    loss = ce + coef * aux
    return loss, {"ce": ce, "aux": aux, "loss": loss}


# ----------------------------------------------------------------- decode
def _init_block_cache(
    kind: str, cfg: ModelConfig, batch: int, max_len: int
) -> Dict:
    if kind == "mamba":
        dims = mamba_dims(cfg)
        gn2 = 2 * dims["n_groups"] * dims["d_state"]
        return {
            "ssm": jnp.zeros(
                (batch, dims["n_heads"], dims["head_dim"], dims["d_state"]),
                dtype=jnp.float32,
            ),
            "conv_x": jnp.zeros(
                (batch, dims["conv_width"] - 1, dims["d_inner"]),
                dtype=jnp.float32,
            ),
            "conv_bc": jnp.zeros(
                (batch, dims["conv_width"] - 1, gn2), dtype=jnp.float32
            ),
        }
    cdt = dt(cfg.compute_dtype)
    # SWA blocks never attend beyond their window → ring buffer of window
    # length (5/6 of gemma3's layers: 32k → 1k cache rows)
    length = max_len
    if kind in ("swa", "swa_moe"):
        length = min(max_len, cfg.sliding_window)
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim_)
    if cfg.kv_cache_dtype == "int8":
        sshape = (batch, length, cfg.n_kv_heads)
        return {
            "k": jnp.zeros(shape, dtype=jnp.int8),
            "v": jnp.zeros(shape, dtype=jnp.int8),
            "k_scale": jnp.zeros(sshape, dtype=jnp.float32),
            "v_scale": jnp.zeros(sshape, dtype=jnp.float32),
        }
    return {"k": jnp.zeros(shape, dtype=cdt), "v": jnp.zeros(shape, dtype=cdt)}


def init_lm_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    pat = cfg.pattern
    g = cfg.n_layers // len(pat)
    cache: Dict[str, Any] = {}
    if g > 0:
        groups = {}
        for i, kind in enumerate(pat):
            one = _init_block_cache(kind, cfg, batch, max_len)
            groups[f"pos{i}"] = jax.tree.map(
                lambda l: jnp.broadcast_to(l[None], (g, *l.shape)).copy(), one
            )
        cache["groups"] = groups
    tail_kinds = cfg.layer_kinds()[g * len(pat) :]
    if tail_kinds:
        cache["tail"] = {
            f"pos{i}": _init_block_cache(kind, cfg, batch, max_len)
            for i, kind in enumerate(tail_kinds)
        }
    return cache


def decode_step(
    params: Dict,
    cache: Dict,
    tokens: jnp.ndarray,  # [B, 1]
    pos_index: jnp.ndarray,  # scalar int32: write position in the cache
    cfg: ModelConfig,
    impl: str = "ref",
    ep_axis: Optional[str] = None,
) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode against a KV/SSM cache; returns (logits [B,1,V],
    new cache)."""
    x = embed(tokens, params["embed"], cfg)
    b = x.shape[0]
    positions = jnp.broadcast_to(
        pos_index.astype(jnp.int32)[None, None], (b, 1)
    )
    pat = cfg.pattern
    g = cfg.n_layers // len(pat)
    shared = params.get("shared")
    new_cache: Dict[str, Any] = {}

    if g > 0:
        def body(x, xs):
            gp, gc = xs
            x, _, nc = _apply_pattern(
                x,
                gp,
                pat,
                positions,
                cfg,
                shared,
                impl,
                ep_axis,
                caches=gc,
                cache_index=pos_index,
            )
            return x, nc

        x, new_groups = jax.lax.scan(
            body, x, (params["groups"], cache["groups"])
        )
        new_cache["groups"] = new_groups

    tail_kinds = cfg.layer_kinds()[g * len(pat) :]
    if tail_kinds:
        x, _, nt = _apply_pattern(
            x,
            params.get("tail", {}),
            tuple(tail_kinds),
            positions,
            cfg,
            shared,
            impl,
            ep_axis,
            caches=cache["tail"],
            cache_index=pos_index,
        )
        new_cache["tail"] = nt

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"], cfg)
    return logits, new_cache
