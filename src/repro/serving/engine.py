"""Batched decode engine: prefill + greedy/temperature decode over a KV (or
SSM-state) cache.

``serve_step`` — one new token for every sequence in the batch against a
cache of ``max_len`` — is the function the decode_* and long_500k dry-run
cells lower (assignment: "``decode_*`` / ``long_*`` lower ``serve_step``,
NOT ``train_step``").
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..checkpoint import decode_array, unflatten_tree
from ..models.registry import ModelApi


def params_from_input(cu_ctx, weights_du: str) -> Any:
    """Model params from a checkpoint DU staged as a CU *input*.

    This is the serving cold-start path: every serve CU declares the
    weights DU in ``input_data``, so each replica's stage-in goes through
    the transfer service — recording a ``du:access`` — and after
    ``promote_after`` accesses the TierManager promotes the DU into the
    site's mem-tier cache.  The rest of the fleet then cold-starts from
    the promoted hot replica instead of re-pulling across the DCN (enable
    with ``tier_cache_bytes``/``tier_auto_promote`` on the Session).
    """
    items = {}
    for rel in cu_ctx.input_manifest(weights_du):
        if rel.startswith("params/") and rel.endswith(".npy"):
            items[rel[7:-4]] = decode_array(cu_ctx.read_input(weights_du, rel))
    return unflatten_tree(items)


def make_serve_step(api: ModelApi) -> Callable:
    """serve_step(params, cache, tokens [B,1], pos) → (next_tokens, cache)."""

    def serve_step(params, cache, tokens, pos_index):
        logits, cache = api.decode_step(params, cache, tokens, pos_index)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tokens[:, None], cache

    return serve_step


@dataclasses.dataclass
class DecodeRequest:
    prompt: jnp.ndarray  # [S] int32
    max_new_tokens: int = 16


class DecodeEngine:
    """Minimal batched engine: static batch, greedy sampling.

    Serving-side Pilot-Data integration (KV segments as DUs, prefix-cache
    affinity) lives in ``repro.training.trainer`` / examples; this class is
    the pure-compute layer.
    """

    def __init__(self, api: ModelApi, params: Any, batch: int, max_len: int):
        self.api = api
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.cache = api.init_cache(batch, max_len)
        self._step = jax.jit(make_serve_step(api))
        self._pos = 0

    @classmethod
    def from_cu_context(
        cls, api: ModelApi, cu_ctx, weights_du: str, batch: int, max_len: int
    ) -> "DecodeEngine":
        """Build a replica engine inside a serve CU, loading weights from
        the (tier-cache-eligible) checkpoint DU declared as its input."""
        return cls(api, params_from_input(cu_ctx, weights_du), batch, max_len)

    def prefill(self, tokens: jnp.ndarray) -> jnp.ndarray:
        """Feed prompt tokens (teacher-forced, one step at a time — a
        production engine would batch this; CPU tests keep prompts short)."""
        b, s = tokens.shape
        assert b == self.batch
        last = None
        for i in range(s):
            last, self.cache = self._step(
                self.params, self.cache, tokens[:, i : i + 1], jnp.int32(self._pos)
            )
            self._pos += 1
        return last

    def generate(self, tokens: jnp.ndarray, max_new_tokens: int) -> jnp.ndarray:
        """Greedy-decode continuation; returns [B, max_new_tokens]."""
        cur = self.prefill(tokens)
        out = [cur]
        for _ in range(max_new_tokens - 1):
            cur, self.cache = self._step(
                self.params, self.cache, cur, jnp.int32(self._pos)
            )
            self._pos += 1
            out.append(cur)
        return jnp.concatenate(out, axis=1)
