from .engine import (
    DecodeEngine,
    DecodeRequest,
    make_serve_step,
    params_from_input,
)

__all__ = [
    "DecodeEngine",
    "DecodeRequest",
    "make_serve_step",
    "params_from_input",
]
