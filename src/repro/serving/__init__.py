from .engine import DecodeEngine, DecodeRequest, make_serve_step

__all__ = ["DecodeEngine", "DecodeRequest", "make_serve_step"]
