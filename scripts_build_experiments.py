"""Generate EXPERIMENTS.md from dry-run artifacts + the perf-iteration log.

Run: PYTHONPATH=src python scripts_build_experiments.py
"""

import glob
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))

from repro.launch.roofline import (  # noqa: E402
    DCN_BW, HBM_BW, ICI_BW, PEAK_FLOPS, derive_terms, levers_table,
    load_cells, roofline_table,
)

OUT = os.path.join(os.path.dirname(__file__), "EXPERIMENTS.md")


def cell_index(mesh):
    return {(c["arch"], c["shape"]): c for c in load_cells(mesh)}


def fmt_gib(b):
    return f"{b/2**30:.1f}"


def dryrun_summary(mesh):
    cells = load_cells(mesh)
    ok = [c for c in cells if c["status"] == "OK"]
    skip = [c for c in cells if c["status"] == "SKIP"]
    fail = [c for c in cells if c["status"] == "FAIL"]
    fit = [c for c in ok if c["memory"]["fits_16GiB"]]
    return cells, ok, skip, fail, fit


def dryrun_table(mesh):
    lines = [
        "| arch | shape | kind | mb | compile s | mem GiB (raw) | mem GiB "
        "(TPU-corr) | fits | HLO flops/dev | coll B/dev | DCN B/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for c in sorted(
        load_cells(mesh), key=lambda c: (c["arch"], order.get(c["shape"], 9))
    ):
        if c["status"] == "SKIP":
            lines.append(f"| {c['arch']} | {c['shape']} | — | — | SKIP (long_500k rule) | | | | | | |")
            continue
        if c["status"] != "OK":
            lines.append(f"| {c['arch']} | {c['shape']} | — | — | FAIL {c.get('error','')[:50]} | | | | | | |")
            continue
        m = c["memory"]
        h = c.get("hlo_analysis", {})
        corr = m.get("peak_per_device_tpu_corrected", m["peak_per_device"])
        dcn = h.get("collective_per_axis", {}).get("pod", 0)
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['kind']} | {c.get('microbatches',1)} "
            f"| {c['compile_s']} | {fmt_gib(m['peak_per_device'])} | {fmt_gib(corr)} "
            f"| {'Y' if m['fits_16GiB'] else 'N'} | {h.get('flops',0):.2e} "
            f"| {h.get('collective_bytes',0):.2e} | {dcn:.2e} |"
        )
    return "\n".join(lines)


def perf_compare(baseline_mesh, opt_mesh, cells):
    base = cell_index(baseline_mesh)
    opt = cell_index(opt_mesh)
    lines = [
        "| cell | variant | mem GiB | compute s | memory s | collective s | "
        "dominant | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key in cells:
        for tag, idx in (("reference-impl", base), ("optimized", opt)):
            c = idx.get(key)
            if not c or c["status"] != "OK":
                continue
            t = derive_terms(c)
            subbed = " (kernel-sub)" if t.get("kernel_substituted") else ""
            lines.append(
                f"| {key[0]} × {key[1]} | {tag}{subbed} | {t['mem_gib']:.1f} "
                f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
                f"| {t['collective_s']:.3e} | {t['dominant']} "
                f"| {t['useful_ratio']:.2f} | {t['roofline_frac']:.3f} |"
            )
    return "\n".join(lines)


def main():
    _, ok_b, skip_b, fail_b, fit_b = dryrun_summary("pod_16x16")
    _, ok_m, skip_m, fail_m, fit_m = dryrun_summary("multipod_2x16x16")
    have_opt = bool(glob.glob(
        os.path.join(os.path.dirname(__file__), "experiments/dryrun/pod_16x16__opt/*.json")
    ))
    _, ok_o, skip_o, fail_o, fit_o = (
        dryrun_summary("pod_16x16__opt") if have_opt else ([],) * 5
    )

    hillclimb_cells = [
        ("gemma3-12b", "train_4k"),
        ("granite-34b", "prefill_32k"),
        ("whisper-large-v3", "train_4k"),
    ]

    doc = f"""# EXPERIMENTS

All dry-run artifacts: ``experiments/dryrun/<mesh>[__<variant>]/``.
Meshes: single-pod ``(data=16, model=16)`` = 256 chips; multi-pod
``(pod=2, data=16, model=16)`` = 512 chips.  Hardware model (TPU v5e):
{PEAK_FLOPS/1e12:.0f} TFLOP/s bf16/chip, {HBM_BW/1e9:.0f} GB/s HBM,
{ICI_BW/1e9:.0f} GB/s/link ICI, {DCN_BW/1e9:.0f} GB/s/chip DCN (pod axis).

Methodology notes (full details in the module docstrings):

* **FLOPs/bytes/collectives are parsed from ``compiled.as_text()``, not
  ``cost_analysis()``** — XLA's cost analysis counts a scanned loop body
  once (verified: an 8-step scanned matmul reports 1/8 the FLOPs), so we
  propagate while-loop trip counts through the computation call graph
  (``repro/launch/hlo_analysis.py``).  FLOPs = dot ops (the MXU term);
  HBM bytes = an each-top-level-op-touches-HBM-once traffic model;
  collective bytes = operand sums per op, classified per mesh axis by
  replica-group stride (pod-axis traffic = DCN).
* **TPU-corrected memory**: the CPU host platform cannot execute bf16
  dots, so XLA hoists fp32 copies of entire stacked weight tensors out of
  the layer scans (measured 10–13 GiB on the large dense archs, identified
  buffer-by-buffer in the HLO).  A real TPU runs bf16 natively and never
  allocates these.  We report raw AND corrected peaks; ``fits`` uses the
  corrected number.  Detection: unique fp32 ``convert`` outputs whose dims
  exactly match a bf16 parameter (``hlo_analysis.cpu_upcast_artifact_bytes``).
* ``decode_*`` / ``long_*`` lower ``serve_step`` (one token against a
  full-length cache); ``train_4k`` lowers ``train_step`` (fwd+bwd+AdamW,
  donated params/opt); ``prefill_32k`` lowers a last-token-logits forward.
* long_500k is SKIPped for the pure full-attention archs per the
  assignment rule (granite-34b, granite-moe, qwen3-moe, whisper, llava) —
  recorded as SKIP rows, not dropped (DESIGN.md §5).

## §Dry-run

**Single-pod 16×16: {len(ok_b)}/40 cells compile OK, {len(skip_b)} SKIP
(long_500k rule), {len(fail_b)} FAIL.**
**Multi-pod 2×16×16: {len(ok_m)}/40 compile OK, {len(skip_m)} SKIP,
{len(fail_m)} FAIL** — the pod axis shards (DCN collective bytes are
non-zero in the table below), which is the multi-pod proof the assignment
asks for.

### Baseline, single-pod (paper-faithful reference implementations)

{dryrun_table("pod_16x16")}

### Baseline, multi-pod (2×16×16)

{dryrun_table("multipod_2x16x16")}

{"### Optimized variant (single-pod; see §Perf for what changed)" if have_opt else ""}

{dryrun_table("pod_16x16__opt") if have_opt else ""}

## §Roofline (single-pod, optimized variant)

Terms in seconds/step/device.  ``useful`` = MODEL_FLOPS / HLO_FLOPs
(MODEL_FLOPS = 6·N_active·D train, 2·N·D prefill, 2·N_active·B decode);
``roofline`` = (MODEL_FLOPS/dev ÷ peak) / max(term) — the §Perf score.

{roofline_table("pod_16x16__opt" if have_opt else "pod_16x16")}

### Per-cell dominant-term levers

{levers_table("pod_16x16__opt" if have_opt else "pod_16x16")}

## §Perf — hypothesis → change → measure → validate

The paper-faithful BASELINE (reference jnp attention, full logits CE,
full-length KV caches, no accumulation) is recorded above and kept in
``experiments/dryrun/pod_16x16/``.  Every optimization below is
beyond-paper (the paper's contribution is the scheduling abstraction; it
prescribes nothing about the step function).  Iterations ran on the three
most interesting cells — worst memory (gemma3-12b × train_4k), worst
overall footprint / prefill representative (granite-34b × prefill_32k),
most collective-bound (whisper-large-v3 × train_4k) — then the winning
changes were applied fleet-wide.

### Iteration log

**I1 — chunked cross-entropy** (gemma3-12b × train_4k)
*Hypothesis*: the [B,S,V] logits dominate memory — per device
16×4096×262144 bf16 ≈ 32 GiB live with fp32 softmax copies; chunking the
CE over 512-token slices with per-chunk remat should remove ~16 GiB.
*Change*: ``layers.chunked_cross_entropy`` (scan + jax.checkpoint), loss
takes hidden states, unembeds per chunk.
*Measured*: 40.6 → 24.3 GiB raw.  **Confirmed** (−16.3 GiB; the other
half of the naive estimate was already being scheduled away by XLA).

**I2 — flash-style blocked attention** (granite-34b × prefill_32k)
*Hypothesis*: the reference attention materializes S×S fp32 scores
(2×48×32768×32768 per device-layer slice ≈ dozens of GiB transient);
a lax.scan over 1024-wide KV tiles with online softmax and a flash-style
custom VJP (recompute tiles in backward, save only (q,k,v,out,lse))
bounds live scores to S×1024 and cuts HBM traffic by ~S/1024 on the
attention term.
*Change*: ``models/blocked_attention.py`` (custom_vjp; validated vs ref
fwd 4e-7 / grad 1e-5), used for every non-decode attention.
*Measured*: 67.9 → 20.8 GiB raw; memory term 168 s → (see table).
**Confirmed** — largest single win in the campaign.

**I3 — windowed ring KV caches** (gemma3-12b × decode_32k)
*Hypothesis*: SWA layers never attend past their window, yet the cache
allocates max_len rows for all layers; gemma3's 5:1 local:global pattern
should shrink 5/6 of its cache from 32k to 1k rows (~6× KV reduction).
*Change*: ring-buffer caches (write at ``pos % window``; slot positions
reconstructed as ``pos − ((pos − j) mod W)``), validated by a
decode-equals-teacher-forcing test across 3 ring wraps.
*Measured*: 28.6 → 12.5 GiB — **fits**.  **Confirmed.**

**I4 — microbatched gradient accumulation**
*Hypothesis*: remaining train-cell excess is live activation footprint ∝
per-device microbatch.
*Measured*: mamba2 26.4→0.9 GiB (mb4, with I2), granite-moe 28.6→4.6
(mb2), zamba2 24.3→9.7 (mb2), whisper 35.1→12.4 (mb2 + blocked
cross-attention) — **confirmed**; but gemma3-12b 24.3→22.2 (mb2) and
granite-34b 33.2→25.0 (mb4) barely moved — **refuted** for the large
dense archs.  The refutation forced a buffer-level look (next).

**I5 — the residual was not ours** (gemma3-12b, granite-34b)
*Hypothesis (from I4's refutation)*: something batch-independent
dominates.  Buffer census of the compiled HLO: fp32 copies of entire
stacked weight tensors (e.g. ``f32[88,6144,1536]`` ×2 = 6.2 GiB)
hoisted out of the scan — the CPU backend upcasts bf16 dots.
*Change*: none to the model — added artifact detection + TPU-corrected
reporting (see Methodology).
*Measured*: corrected peaks — granite-34b train 25.0→~13 GiB,
prefill 20.5→~10.5 GiB, gemma3-12b train 22.2→~15 GiB: **all cells fit**
on the corrected accounting.  **Confirmed** by buffer-level census.

**I6 — prefill batch chunking** (granite-34b × prefill_32k)
*Hypothesis*: prefill live set scales with per-device batch → lax.map
over 2 chunks halves it.
*Measured*: 20.8 → 20.5 GiB raw.  **Refuted** — live set was the I5
artifact + per-layer weights, not activations.  Kept where the chunked
batch still divides the DP axes; a follow-up bug showed why the guard
matters: on the multi-pod mesh a 16-wide chunk over 32 DP devices
REPLICATED activations across DP (measured 153× FLOPs blowup on
qwen3-moe × prefill) — fixed by disabling chunking when divisibility
would break.

**I7 — kernel substitution in the roofline** (all non-decode cells)
*Hypothesis*: the op-level traffic model charges the scan-based flash
attention / SSD implementations a full HBM round trip for carries that
the Pallas kernels keep in VMEM scratch — the memory term should be
computed with kernel traffic for those regions (on a real TPU dry-run the
kernels appear as opaque custom-calls and must be hand-modeled the same
way).
*Change*: ``launch/kernel_substitution.py`` — each cell's attention/SSD
scans are lowered STANDALONE at the cell's per-device shard geometry and
measured under the SAME analyzer, then replaced by the kernel's analytic
traffic (q/k/v/o streamed once fwd, 3× for the recompute backward; SSD
x/dA/B/C/y once).  Kernel FLOPs also account for causal/window block
skipping (2× / S→W reductions the jnp path cannot express).
*Measured*: attention-scan traffic was 35–40 % of the big dense cells'
modeled bytes (granite-34b train: 3.7e13 of 9.9e13 B/dev) and replacing
it moves the memory term accordingly — see the roofline table
("substituted" column = final numbers).  **Confirmed.**

**I8 — MoE aux reduction correctness under partial sharding**
(qwen3-moe × prefill_32k, multi-pod)
Not a perf win — a correctness fix found BY the sweep: the expert-parallel
``pmean`` reduced over all mesh axes even when the chunked batch left the
tokens invarying over DP, which the shard_map type checker rejects.  The
reduce-axes set now matches the axes the tokens actually vary over.

**I9 — fusion-simulated traffic model** (all cells)
*Hypothesis (from a per-op byte census of granite-34b × train)*: 22 % of
modeled traffic was unfused ``convert`` ops and ~25 % more was top-level
elementwise/copy/transpose ops — the CPU backend barely fuses; the TPU
backend would fold these into fusion regions that read external inputs
once and write outputs once, so the naive every-op-round-trips model
overstates the memory term ~2×.
*Change*: the analyzer now union-finds maximal connected elementwise
regions per computation and charges each region its external inputs +
outputs once (artifact weight-upcasts excluded entirely); non-elementwise
ops (dot, fusion, reduce, slice/DUS, collectives) charge as before.
*Measured*: granite-34b × train modeled bytes 9.86e13 → 6.18e13 per
device before kernel substitution.  **Confirmed**; all tables regenerated
under the fused model (the metric version used throughout this file).

**I10 — int8 KV caches** (decode cells)
*Hypothesis*: decode is KV-streaming bound (the levers list has said so
since the baseline table); per-token-per-head symmetric int8 quantization
halves cache bytes AND cache traffic at <1 % logit error.
*Change*: ``kv_cache_dtype="int8"`` — int8 k/v + fp32 per-(token, head)
scales, quantize-on-write, dequantize fused into the attention read;
composes with the ring-buffer windowed caches (I3).  Decode-vs-teacher-
forcing consistency test bounds relative error at 0.8 %.
*Measured* (decode memory term, seconds/step/device, bf16 → int8):
gemma3-12b 0.378 → 0.164 (2.3×), granite-34b 2.62 → 1.63 (1.6×),
qwen3-moe 2.00 → 0.79 (2.5×), zamba2 0.147 → 0.032 (4.6×), and
long_500k gemma3-12b 0.138 → 0.115.  **Confirmed** (whisper is unchanged —
the enc-dec cache path does not yet implement quantization; noted as
future work).  Artifacts: ``experiments/dryrun/pod_16x16__opt_kv8/``.

**I11 — bf16 gradient accumulation** (gemma3-12b × train_4k, the one
remaining over-budget cell)
*Hypothesis*: the residual ~18 GiB is fp32 accumulator footprint
(accumulating grads in param dtype halves it; the fp32 optimizer masters
absorb rounding across steps).
*Measured*: 19.09 → 18.92 GiB at mb=4.  **Refuted** — a buffer census
shows the residual is ~1.9 GiB × several aliases of an fp32
half-vocab×d_model buffer in the tied-embedding master/update path (the
262k-vocab table's ZeRO gather).  gemma3-12b × train_4k therefore stays
over the v5e budget (18.2 GiB corrected at mb=4; 34/35 cells fit).
Identified levers, unimplemented: untie the embedding (params +1 GiB but
removes the gathered fp32 update path), a vocab-sharded master update
that never re-gathers (custom collective schedule), or a v5p-class part.
The knob (``accum_dtype``) is kept — it is the right default for
memory-constrained non-tied archs.

### Baseline vs optimized, hillclimbed cells

NOTE on labels: "reference-impl" rows use the unfused reference attention
path; they were re-lowered under the final (fusion-simulated, I9) metric so
the two rows are apples-to-apples, and they inherit the memory fixes that
became defaults (chunked CE, ring caches).  The ORIGINAL paper-faithful
baseline peaks — before any of I1–I4 — are the ones quoted in the
iteration log (gemma3-12b train 40.6 GiB, granite-34b prefill 67.9 GiB,
whisper train 35.1 GiB, gemma3-12b decode 28.6 GiB raw).

{perf_compare("pod_16x16", "pod_16x16__opt", hillclimb_cells) if have_opt else "(optimized sweep pending)"}

### Where this lands, and what is left on the table

* The optimized variant turns every previously-over-budget cell into a
  fitting one (TPU-corrected); the dominant term across most cells remains
  **memory** under our conservative traffic model — the model charges
  every top-level HLO op a full HBM round trip, while a real TPU fuses
  dot epilogues and keeps flash-attention tiles in VMEM (the Pallas
  kernels in ``repro/kernels`` exist for exactly this; they cannot lower
  on the CPU host platform, so their effect shows up as the blocked-
  attention traffic reduction rather than a custom-call).
* Next levers, in expected-win order (napkin math in the levers list
  above): (1) int8 KV caches for decode (2× on the decode memory term);
  (2) fusing the SSD intra-chunk path (the ssd_scan kernel) — mamba2
  cells still carry fp32 chunk intermediates; (3) DCN gradient
  compression (``optim/compression.py`` is implemented and unit-tested;
  wiring it into the pod-axis grad reduction halves the multi-pod
  collective term for the train cells where DCN bytes ≈ ICI bytes).

## §Scale / fault tolerance (runtime evidence)

Not a dry-run claim — these run as tests/benchmarks on the real runtime:

* checkpoint/restart: ``test_training_survives_pilot_failure`` kills the
  only data-local pilot mid-chunk; the heartbeat monitor requeues, a
  standby pilot replays from the checkpoint-DU chain, the run completes.
* elastic scaling: ``test_elastic_scale_up_mid_run`` adds a pilot mid-run;
  it takes over chunks.
* straggler mitigation: ``test_straggler_duplication_exactly_once`` —
  duplicate launch + winner-CAS.
* paper-figure benchmarks (Figs. 7–13 analogues): ``python -m
  benchmarks.run`` — staging/backends, group-vs-sequential replication,
  five placement strategies, the 1024-task multi-machine ensemble with
  and without replication, §6.1 calculus-vs-oracle.
"""
    with open(OUT, "w") as fh:
        fh.write(doc)
    print(f"wrote {OUT} ({len(doc)} chars)")


if __name__ == "__main__":
    main()
