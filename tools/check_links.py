#!/usr/bin/env python3
"""Docs link checker (stdlib only) — CI's docs job runs this.

Two classes of reference are verified across README.md, ROADMAP.md and
docs/*.md:

1. **Markdown links** ``[text](target)`` — a relative target must exist
   on disk (external ``http(s)://`` / ``mailto:`` targets are skipped),
   and a ``#fragment`` pointing into a markdown file must match one of
   that file's heading anchors (GitHub slug rules).
2. **Source pointers** — backtick code spans that look like repo paths
   (``src/repro/core/session.py``, ``benchmarks/run.py``,
   ``tests/test_pdlint.py`` …) must resolve, so a doc can never name a
   module that was moved or deleted.  Spans containing globs, spaces or
   placeholder braces are ignored.

Exit 0 when everything resolves; otherwise print one ``file:line:``
diagnostic per broken reference and exit 1.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import Iterator, List, Tuple

REPO = Path(__file__).resolve().parent.parent

#: code-span path roots worth verifying (a span must start with one)
PATH_ROOTS = (
    "src/",
    "tests/",
    "benchmarks/",
    "examples/",
    "docs/",
    "tools/",
    ".github/",
)

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
CODE_SPAN_RE = re.compile(r"`([^`]+)`")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")


def doc_files() -> List[Path]:
    files = [REPO / "README.md", REPO / "ROADMAP.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def github_slug(heading: str) -> str:
    """The anchor GitHub generates for a heading line."""
    text = re.sub(r"`([^`]*)`", r"\1", heading).strip().lower()
    text = re.sub(r"[^\w\s-]", "", text, flags=re.UNICODE)
    return re.sub(r"\s+", "-", text)


def anchors_of(md: Path) -> set:
    slugs, seen = set(), {}
    for line in md.read_text(encoding="utf-8").splitlines():
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = github_slug(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def iter_lines_outside_fences(md: Path) -> Iterator[Tuple[int, str]]:
    fenced = False
    for lineno, line in enumerate(
        md.read_text(encoding="utf-8").splitlines(), start=1
    ):
        if line.lstrip().startswith("```"):
            fenced = not fenced
            continue
        if not fenced:
            yield lineno, line


def check_file(md: Path) -> List[str]:
    errors: List[str] = []
    rel = md.relative_to(REPO)
    for lineno, line in iter_lines_outside_fences(md):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, fragment = target.partition("#")
            if path_part:
                dest = (md.parent / path_part).resolve()
            else:
                dest = md.resolve()  # same-file fragment
            if not dest.exists():
                errors.append(
                    f"{rel}:{lineno}: broken link target {target!r}"
                )
                continue
            if fragment and dest.suffix == ".md":
                if fragment not in anchors_of(dest):
                    errors.append(
                        f"{rel}:{lineno}: no heading for anchor "
                        f"{target!r}"
                    )
        for m in CODE_SPAN_RE.finditer(line):
            span = m.group(1)
            if not span.startswith(PATH_ROOTS):
                continue
            # skip globs, placeholders, multi-token commands, sets
            if any(ch in span for ch in "{}*<>… ") or span.endswith("."):
                continue
            if not (REPO / span).exists():
                errors.append(
                    f"{rel}:{lineno}: source pointer `{span}` "
                    "does not resolve"
                )
    return errors


def main() -> int:
    all_errors: List[str] = []
    files = doc_files()
    for md in files:
        all_errors.extend(check_file(md))
    if all_errors:
        print("\n".join(all_errors))
        print(f"\n{len(all_errors)} broken reference(s)", file=sys.stderr)
        return 1
    print(f"checked {len(files)} files: all links and pointers resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
