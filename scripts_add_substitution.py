"""Annotate dry-run artifacts with the kernel-substitution analysis (§Perf
iteration I7).  PYTHONPATH=src python scripts_add_substitution.py [glob...]"""
import glob, json, os, sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "src"))
from repro.configs import get_config, get_shape
from repro.launch.kernel_substitution import substitution_for_cell

paths = []
for pat in (sys.argv[1:] or ["experiments/dryrun/pod_16x16__opt/*.json",
                             "experiments/dryrun/multipod_2x16x16__opt/*.json"]):
    paths.extend(glob.glob(pat))
for p in sorted(paths):
    with open(p) as fh:
        cell = json.load(fh)
    if cell.get("status") != "OK":
        continue
    dp = 32 if "multipod" in cell["mesh"] else 16
    sub = substitution_for_cell(
        get_config(cell["arch"]), get_shape(cell["shape"]),
        dp=dp, tp=16, mb=cell.get("microbatches", 1),
    )
    cell["kernel_substitution"] = sub
    with open(p, "w") as fh:
        json.dump(cell, fh, indent=1)
    print(f"{os.path.basename(p)}: scan={sub['measured_scan_bytes']:.2e}B "
          f"kernel={sub['kernel_bytes']:.2e}B delta={sub['bytes_delta']:.2e}B")
