"""Figs. 9–10 — "Genome Sequencing Using Pilot-Data on Different
Infrastructures": five data/compute placement strategies for an 8-task
ensemble with a large shared input DU + partitioned per-task DUs.

This bench runs the REAL runtime (real scheduler, agents, replica caching)
— only the transfer clock is simulated, calibrated to the paper's setting:
~8 GB shared reference + 8 × 256 MB partitions.  Real bytes are scaled
1 MB : 1 GB.

Scenarios (paper numbering):
  1. naive/OSG    — 8 single-slot pilots across OSG sites, every task pulls
                    all input from the submission host;
  2. naive/XSEDE  — one 8-slot pilot on Lonestar, same naive pulls;
  3. PD+iRODS/OSG — input group-replicated to all OSG sites up front, tasks
                    link locally (pays T_D once);
  4. PD+SSH/XSEDE — input staged once to Lonestar shared-FS PD, tasks link;
  5. multi-infra  — PD on Lonestar, pilots on BOTH Lonestar and OSG: the
                    affinity scheduler sends most tasks to the data.

Claims to reproduce: scenarios 3–5 beat 1–2; per-task staging collapses
when PDs are co-located; in scenario 5 data-local pilots get most tasks.
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core import (
    CUState,
    DataUnitDescription,
    FUNCTIONS,
    PilotManager,
    Topology,
    list_strategies,
    replicate_group,
)

from .common import MB, emit, modeled_makespan

#: the five registered placement plugins this bench exercises in both
#: scheduler modes (acceptance: identical decisions sync vs async)
STRATEGIES = ("cost", "data-local", "queue-depth", "round-robin", "random")

SCALE = 1e-3  # real bytes per simulated byte (1 MB : 1 GB)
REF_BYTES = int(8 * 1e9 * SCALE)  # 8 GB shared reference
PART_BYTES = int(0.256 * 1e9 * SCALE)  # 256 MB per-task partition
N_TASKS = 8
TASK_COMPUTE_S = 300.0  # simulated per-task compute (BWA-scale)

OSG_SITES = [f"osg:site{i}" for i in range(8)]
LONESTAR = "xsede:lonestar"
SUBMISSION = "submission"


def _topology() -> Topology:
    topo = Topology()
    topo.register(SUBMISSION, bandwidth=12 * MB, latency=0.05)  # gateway node
    topo.register(LONESTAR, bandwidth=40 * MB, latency=0.02)
    for i, s in enumerate(OSG_SITES):
        topo.register(s, bandwidth=(14 + 4 * i) * MB, latency=0.05)
    return topo


def _workload(mgr: PilotManager, tag: str, target_pd=None):
    FUNCTIONS.register(f"bwa:{tag}", lambda cu_ctx: "aligned")
    ref = mgr.cds.submit_data_unit(
        DataUnitDescription(
            name=f"ref-{tag}", files={"genome.fa": b"G" * REF_BYTES}
        ),
        target=target_pd,
    )
    parts = [
        mgr.cds.submit_data_unit(
            DataUnitDescription(
                name=f"reads{i}-{tag}",
                files={f"reads{i}.fq": b"R" * PART_BYTES},
            ),
            target=target_pd,
        )
        for i in range(N_TASKS)
    ]
    return ref, parts


def _ingest_td(mgr) -> float:
    """One-time simulated cost of staging the inputs from the submission
    host into their first PD (the paper's T_D inset, Fig. 9)."""
    return sum(
        r.sim_seconds for r in mgr.transfer.records() if r.src_pd is None
    ) / SCALE


def _run_tasks(mgr, tag, ref, parts, pilot=None, affinity=None, cache=True):
    cus = [
        mgr.session.submit_cu(
            executable=f"bwa:{tag}",
            input_data=[ref, parts[i]],
            pilot=pilot.id if pilot else None,
            affinity=affinity,
            sim_compute_s=TASK_COMPUTE_S,
            cache_inputs=cache,
        )
        for i in range(N_TASKS)
    ]
    assert mgr.wait(timeout=60), "workload did not finish"
    for cu in cus:
        assert cu.state == CUState.DONE, (cu.state, cu.error)
    return cus


def _makespan(
    cus, pilots, t_d: float = 0.0, serialize_staging: bool = False
) -> float:
    """Replay recorded (sim_stage + sim_compute) onto each pilot's slots.

    ``serialize_staging``: naive scenarios pull everything through the one
    submission-host uplink — concurrent pulls contend, so staging
    serializes globally (the paper's "file staging quickly becomes a
    bottleneck", Fig. 10)."""
    if serialize_staging:
        total_stage = sum(cu.timings.sim_stage_s / SCALE for cu in cus)
        by_pilot: Dict[str, List[float]] = {}
        for cu in cus:
            by_pilot.setdefault(cu.pilot_id, []).append(
                cu.description.sim_compute_s
            )
        spans = [
            modeled_makespan(ds, next(p.slots for p in pilots if p.id == pid))
            for pid, ds in by_pilot.items()
        ]
        return t_d + total_stage + max(spans)
    by_pilot = {}
    for cu in cus:
        d = (cu.timings.sim_stage_s / SCALE) + cu.description.sim_compute_s
        by_pilot.setdefault(cu.pilot_id, []).append(d)
    spans = [
        modeled_makespan(ds, next(p.slots for p in pilots if p.id == pid))
        for pid, ds in by_pilot.items()
    ]
    return t_d + max(spans)


def _strategy_decisions(strategy: str, mode: str, n_cus: int = 8) -> List[str]:
    """Placement sequence (pilot indices) for one strategy in one scheduler
    mode, on a frozen workload: pilots accept no work (slots=0), so the
    decision stream depends only on the submissions and the strategy."""
    mgr = PilotManager(
        topology=_topology(),
        scheduler_mode=mode,
        placement_strategy=strategy,
    )
    mgr.ctx.submission_label = SUBMISSION
    try:
        pd = mgr.start_pilot_data(
            service_url=f"sharedfs://{LONESTAR}/pd-eq", affinity=LONESTAR
        )
        pilots = [
            mgr.start_pilot(resource_url=f"sim://{s}", slots=0)
            for s in (LONESTAR, *OSG_SITES[:3])
        ]
        [p.wait_active() for p in pilots]
        index = {p.id: str(i) for i, p in enumerate(pilots)}
        FUNCTIONS.register(f"eq:{strategy}:{mode}", lambda cu_ctx: "ok")
        du = mgr.cds.submit_data_unit(
            DataUnitDescription(name="eq-in", files={"x": b"e" * (1 << 20)}),
            target=pd,
        )
        du.wait()
        for i in range(n_cus):
            mgr.session.submit_cu(
                executable=f"eq:{strategy}:{mode}",
                input_data=[du] if i % 2 == 0 else [],
            )
        deadline = time.monotonic() + 15
        while (
            len(mgr.cds.decisions()) < n_cus and time.monotonic() < deadline
        ):
            time.sleep(0.005)
        ds = mgr.cds.decisions()
        assert len(ds) == n_cus, f"{strategy}/{mode}: {len(ds)} decisions"
        return [index[d["pilot"]] for d in ds]
    finally:
        mgr.shutdown()


def _strategy_equivalence(rows: List[str]) -> None:
    """The five registered plugins, sync vs async: decisions must match."""
    registered = set(list_strategies())
    assert set(STRATEGIES) <= registered, registered
    all_agree = True
    for strat in STRATEGIES:
        sync_seq = _strategy_decisions(strat, "sync")
        async_seq = _strategy_decisions(strat, "async")
        agree = sync_seq == async_seq
        all_agree &= agree
        rows.append(
            emit(
                f"placement.strategy.{strat}.modes_agree",
                0.0,
                f"{agree};seq={''.join(sync_seq)}",
            )
        )
    rows.append(
        emit("placement.claim.strategies_sync_async_agree", 0.0, str(all_agree))
    )


def run() -> List[str]:
    rows = []
    results = {}
    task_split: Dict[str, Dict[str, int]] = {}

    # ---- placement plugins: five strategies × two scheduler modes ------
    _strategy_equivalence(rows)

    # ---- scenario 1: naive pulls, 8 OSG pilots -------------------------
    mgr = PilotManager(topology=_topology())
    mgr.ctx.submission_label = SUBMISSION
    pilots = [
        mgr.start_pilot(resource_url=f"sim://{s}", slots=1) for s in OSG_SITES
    ]
    [p.wait_active() for p in pilots]
    ref, parts = _workload(mgr, "s1")
    cus = _run_tasks(mgr, "s1", ref, parts, cache=False)
    results["s1_naive_osg"] = _makespan(cus, pilots, serialize_staging=True)
    mgr.shutdown()

    # ---- scenario 2: naive pulls, one 8-slot XSEDE pilot ---------------
    mgr = PilotManager(topology=_topology())
    mgr.ctx.submission_label = SUBMISSION
    p = mgr.start_pilot(resource_url=f"sim://{LONESTAR}", slots=8)
    p.wait_active()
    ref, parts = _workload(mgr, "s2")
    cus = _run_tasks(mgr, "s2", ref, parts, pilot=p, cache=False)
    results["s2_naive_xsede"] = _makespan(cus, [p], serialize_staging=True)
    mgr.shutdown()

    # ---- scenario 3: group-replicated PDs on OSG (iRODS-style) ---------
    mgr = PilotManager(topology=_topology())
    mgr.ctx.submission_label = SUBMISSION
    pds = [
        mgr.start_pilot_data(service_url=f"mem://{s}/pd-s3", affinity=s)
        for s in OSG_SITES
    ]
    pilots = [
        mgr.start_pilot(resource_url=f"sim://{s}", slots=1) for s in OSG_SITES
    ]
    [p.wait_active() for p in pilots]
    ref, parts = _workload(mgr, "s3", target_pd=pds[0])
    t_d = _ingest_td(mgr) + replicate_group(ref, pds[0], pds[1:], mgr.ctx) / SCALE
    cus = _run_tasks(mgr, "s3", ref, parts)
    results["s3_pd_osg_replicated"] = _makespan(cus, pilots, t_d=t_d)
    rows.append(emit("placement.s3.T_D_replication", t_d * 1e6, f"{t_d:.0f}s"))
    mgr.shutdown()

    # ---- scenario 4: PD on Lonestar shared FS --------------------------
    mgr = PilotManager(topology=_topology())
    mgr.ctx.submission_label = SUBMISSION
    pd = mgr.start_pilot_data(
        service_url=f"sharedfs://{LONESTAR}/scratch-s4", affinity=LONESTAR
    )
    p = mgr.start_pilot(resource_url=f"sim://{LONESTAR}", slots=8)
    p.wait_active()
    ref, parts = _workload(mgr, "s4", target_pd=pd)
    t_d4 = _ingest_td(mgr)
    cus = _run_tasks(mgr, "s4", ref, parts, pilot=p)
    results["s4_pd_xsede_sharedfs"] = _makespan(cus, [p], t_d=t_d4)
    rows.append(emit("placement.s4.T_D_ingest", t_d4 * 1e6, f"{t_d4:.0f}s"))
    mgr.shutdown()

    # ---- scenario 5: PD on Lonestar, pilots on XSEDE + OSG -------------
    mgr = PilotManager(topology=_topology())
    mgr.ctx.submission_label = SUBMISSION
    pd = mgr.start_pilot_data(
        service_url=f"sharedfs://{LONESTAR}/scratch-s5", affinity=LONESTAR
    )
    p_ls = mgr.start_pilot(resource_url=f"sim://{LONESTAR}", slots=6)
    p_osg = [
        mgr.start_pilot(resource_url=f"sim://{s}", slots=1)
        for s in OSG_SITES[:4]
    ]
    p_ls.wait_active()
    [p.wait_active() for p in p_osg]
    ref, parts = _workload(mgr, "s5", target_pd=pd)
    t_d5 = _ingest_td(mgr)
    cus = _run_tasks(mgr, "s5", ref, parts)
    results["s5_multi_infra"] = _makespan(cus, [p_ls, *p_osg], t_d=t_d5)
    local = sum(1 for cu in cus if cu.pilot_id == p_ls.id)
    task_split["s5"] = {"lonestar": local, "osg": N_TASKS - local}
    rows.append(
        emit(
            "placement.s5.tasks_on_data_local_pilot",
            0.0,
            f"{local}/{N_TASKS}",
        )
    )
    # Fig. 10: per-task staging breakdown
    stages = [cu.timings.sim_stage_s / SCALE for cu in cus]
    rows.append(
        emit(
            "placement.s5.stage_seconds_minmax",
            0.0,
            f"min={min(stages):.0f};max={max(stages):.0f}",
        )
    )
    mgr.shutdown()

    for name, t in results.items():
        rows.append(emit(f"placement.{name}.makespan", t * 1e6, f"T={t:.0f}s"))
    # paper claims
    best_pd = min(results["s3_pd_osg_replicated"], results["s4_pd_xsede_sharedfs"], results["s5_multi_infra"])
    worst_naive = min(results["s1_naive_osg"], results["s2_naive_xsede"])
    rows.append(
        emit(
            "placement.claim.pd_beats_naive",
            0.0,
            str(best_pd < worst_naive),
        )
    )
    rows.append(
        emit(
            "placement.claim.s5_majority_data_local",
            0.0,
            str(task_split["s5"]["lonestar"] > N_TASKS // 2),
        )
    )
    return rows


if __name__ == "__main__":
    run()
