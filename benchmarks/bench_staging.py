"""Fig. 7 — "Pilot-Data on Different Infrastructures": staging time T_S to
populate a Pilot-Data across backend classes, vs dataset size.

The paper's qualitative findings this bench must reproduce:
  * SRM(+GridFTP) best for bulk transfers,
  * SSH beats Globus Online for small datasets (setup cost), GO wins at
    large sizes (GridFTP bandwidth behind service overhead),
  * iRODS ≈ SSH-class plus catalog overhead,
  * S3 grows linearly, WAN-bandwidth limited.
"""

from __future__ import annotations

from typing import Dict, List

from .common import GB, PAPER_PROFILES, emit


def staging_time(profile, nbytes: float, n_files: int = 8) -> float:
    """T_S = per-request setup + transfer + registration (per file set)."""
    return (
        profile.op_latency
        + nbytes / profile.bandwidth
        + n_files * profile.register_latency
    )


def run(sizes_gb=(0.1, 0.5, 1.0, 2.0, 4.0)) -> List[str]:
    rows = []
    results: Dict[str, Dict[float, float]] = {}
    for name, prof in PAPER_PROFILES.items():
        results[name] = {}
        for size in sizes_gb:
            ts = staging_time(prof, size * GB)
            results[name][size] = ts
            rows.append(
                emit(f"staging.{name}.{size}GB", ts * 1e6, f"T_S={ts:.1f}s")
            )
    # paper-claim checks (soft asserts reported as derived values)
    small, big = sizes_gb[0], sizes_gb[-1]
    checks = {
        "srm_best_bulk": results["srm"][big]
        == min(r[big] for r in results.values()),
        "ssh_beats_GO_small": results["ssh"][small]
        < results["globus_online"][small],
        "GO_beats_ssh_big": results["globus_online"][big]
        < results["ssh"][big],
        "s3_slowest_big": results["s3"][big]
        == max(r[big] for r in results.values()),
    }
    for k, v in checks.items():
        rows.append(emit(f"staging.claim.{k}", 0.0, str(v)))
    return rows


if __name__ == "__main__":
    run()
