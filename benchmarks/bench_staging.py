"""Fig. 7 — "Pilot-Data on Different Infrastructures": staging time T_S to
populate a Pilot-Data across backend classes, vs dataset size — plus the
chunk-layer extension: multi-source **striped** stage-in vs single-source
monolithic stage-in across partial-holder topologies.

The paper's qualitative findings this bench must reproduce:
  * SRM(+GridFTP) best for bulk transfers,
  * SSH beats Globus Online for small datasets (setup cost), GO wins at
    large sizes (GridFTP bandwidth behind service overhead),
  * iRODS ≈ SSH-class plus catalog overhead,
  * S3 grows linearly, WAN-bandwidth limited.

Chunk-layer claim (tentpole acceptance): with N partial holders each
holding a distinct chunk stripe, a cold stage-in that stripes each missing
chunk from its cheapest holder in parallel waves beats pulling the whole
DU monolithically from the one full replica — and the advantage grows
with N.
"""

from __future__ import annotations

from typing import Dict, List

from .common import GB, MB, PAPER_PROFILES, emit


def staging_time(profile, nbytes: float, n_files: int = 8) -> float:
    """T_S = per-request setup + transfer + registration (per file set)."""
    return (
        profile.op_latency
        + nbytes / profile.bandwidth
        + n_files * profile.register_latency
    )


#: striped-stage-in scenario: real bytes per simulated byte (1 MB : 1 GB)
STRIPE_SCALE = 1e-3
STRIPE_GB = 8.0


def _striped_case(n_holders: int) -> Dict[str, float]:
    """One partial-holder topology: an origin full replica + ``n_holders``
    sites each holding a distinct 1/N chunk stripe, all at equal topology
    distance from the destination.  Returns the simulated T_S of the
    monolithic single-source pull vs the multi-source striped fetch."""
    from repro.core import DataUnitDescription, PilotManager, Topology

    topo = Topology()
    labels = [f"stripe:origin", *[f"stripe:h{i}" for i in range(n_holders)],
              "stripe:dst"]
    for lbl in labels:
        topo.register(lbl, bandwidth=30 * MB, latency=0.05)
    mgr = PilotManager(topology=topo)
    try:
        origin = mgr.start_pilot_data(
            service_url=f"mem://stripe:origin/src{n_holders}",
            affinity="stripe:origin",
        )
        nbytes = int(STRIPE_GB * GB * STRIPE_SCALE)
        du = mgr.cds.submit_data_unit(
            DataUnitDescription(
                name=f"striped-{n_holders}", files={"blob": b"s" * nbytes}
            ),
            target=origin,
        )
        du.wait()
        dst_a = mgr.start_pilot_data(
            service_url=f"mem://stripe:dst/mono{n_holders}", affinity="stripe:dst"
        )
        dst_b = mgr.start_pilot_data(
            service_url=f"mem://stripe:dst/striped{n_holders}",
            affinity="stripe:dst",
        )
        # monolithic: the paper's naive mode — whole DU from the one full
        # replica, sandbox never becomes a holder
        t_mono = mgr.transfer.stage_in(
            du, dst_a, "stripe:dst", use_cache=False
        ) / STRIPE_SCALE
        # disperse distinct chunk stripes onto the partial holders
        holders = [
            mgr.start_pilot_data(
                service_url=f"mem://stripe:h{i}/pd", affinity=f"stripe:h{i}"
            )
            for i in range(n_holders)
        ]
        stripes: List[List[int]] = [[] for _ in range(n_holders)]
        for c in range(du.n_chunks):
            stripes[c % n_holders].append(c)
        for pd, stripe in zip(holders, stripes):
            mgr.transfer.replicate_chunks(du, origin, pd, stripe)
        # striped: every missing chunk from its cheapest holder, parallel
        # waves (T = max over per-source groups)
        t_striped = mgr.transfer.stage_in(
            du, dst_b, "stripe:dst"
        ) / STRIPE_SCALE
        sources = {
            r.src_pd
            for r in mgr.transfer.records()
            if r.dst_pd == dst_b.id and not r.linked
        }
        return {
            "t_mono": t_mono,
            "t_striped": t_striped,
            "n_sources": float(len(sources)),
        }
    finally:
        mgr.shutdown()


def run(sizes_gb=(0.1, 0.5, 1.0, 2.0, 4.0)) -> List[str]:
    rows = []
    results: Dict[str, Dict[float, float]] = {}
    for name, prof in PAPER_PROFILES.items():
        results[name] = {}
        for size in sizes_gb:
            ts = staging_time(prof, size * GB)
            results[name][size] = ts
            rows.append(
                emit(f"staging.{name}.{size}GB", ts * 1e6, f"T_S={ts:.1f}s")
            )
    # paper-claim checks (soft asserts reported as derived values)
    small, big = sizes_gb[0], sizes_gb[-1]
    checks = {
        "srm_best_bulk": results["srm"][big]
        == min(r[big] for r in results.values()),
        "ssh_beats_GO_small": results["ssh"][small]
        < results["globus_online"][small],
        "GO_beats_ssh_big": results["globus_online"][big]
        < results["ssh"][big],
        "s3_slowest_big": results["s3"][big]
        == max(r[big] for r in results.values()),
    }
    for k, v in checks.items():
        rows.append(emit(f"staging.claim.{k}", 0.0, str(v)))
    # ---- chunk layer: multi-source striped vs monolithic stage-in -------
    all_beat = True
    for n_holders in (2, 4):
        r = _striped_case(n_holders)
        beat = r["t_striped"] < r["t_mono"]
        all_beat &= beat
        rows.append(
            emit(
                f"staging.striped.h{n_holders}.t_mono",
                r["t_mono"] * 1e6,
                f"T_S={r['t_mono']:.1f}s",
            )
        )
        rows.append(
            emit(
                f"staging.striped.h{n_holders}.t_striped",
                r["t_striped"] * 1e6,
                f"T_S={r['t_striped']:.1f}s;sources={int(r['n_sources'])}",
            )
        )
        rows.append(
            emit(
                f"staging.claim.striped_beats_mono.h{n_holders}",
                0.0,
                str(beat),
            )
        )
    rows.append(
        emit("staging.claim.striped_beats_mono_all", 0.0, str(all_beat))
    )
    return rows


if __name__ == "__main__":
    run()
