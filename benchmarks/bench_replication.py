"""Fig. 8 — "Using Replication on OSG": T_R for group vs sequential
replication to a 9-site pool, vs dataset size; plus the per-host T_X
distribution (the paper's inset) and the chunk-layer extension:
**chunk-striped** group replication (disperse distinct chunk stripes, then
heal every target from the many partial holders) vs the classic
**monolithic** whole-DU fan-out.

Uses the real replication machinery (live PilotData + TransferService) on a
paper-shaped grid topology with heterogeneous site uplinks — the group
strategy must beat sequential (striped group by a larger margin than
monolithic group), and the per-host spread must match the paper's inset.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core import DataUnitDescription, PilotManager, estimate_tx, make_grid_topology, replicate_group, replicate_sequential

from .common import GB, MB, emit

#: 9 OSG-ish sites with heterogeneous uplinks (paper: "different sites have
#: very different performance characteristics")
SITES = [
    ("osg:tacc", 40 * MB), ("osg:purdue", 30 * MB), ("osg:cornell", 22 * MB),
    ("osg:fnal", 55 * MB), ("osg:ucsd", 18 * MB), ("osg:wisc", 34 * MB),
    ("osg:unl", 12 * MB), ("osg:uchicago", 28 * MB), ("osg:bnl", 20 * MB),
]
SRC = ("osg:fermilab-central", 60 * MB)  # paper: central iRODS at Fermilab


def _setup(size_bytes: int, tag: str):
    topo = make_grid_topology(
        [(lbl, bw, 0.02) for lbl, bw in [SRC, *SITES]]
    )
    mgr = PilotManager(topology=topo)
    src_pd = mgr.start_pilot_data(
        service_url=f"mem://{SRC[0]}/src-{tag}", affinity=SRC[0]
    )
    targets = [
        mgr.start_pilot_data(
            service_url=f"mem://{lbl}/repl-{tag}", affinity=lbl
        )
        for lbl, _ in SITES
    ]
    du = mgr.cds.submit_data_unit(
        DataUnitDescription(
            name=f"dataset-{tag}", files={"data.bin": b"x" * size_bytes}
        ),
        target=src_pd,
    )
    du.wait()
    return mgr, src_pd, targets, du


def run(sizes_gb=(1.0, 2.0, 4.0), scale=1e-3) -> List[str]:
    """``scale``: real bytes per simulated byte (1 MB stands in for 1 GB —
    the virtual clock uses topology bandwidths against *simulated* sizes via
    profile math, so only relative composition matters)."""
    rows = []
    for size in sizes_gb:
        real = int(size * GB * scale)
        modes = (
            ("group", lambda du, s, t, ctx: replicate_group(du, s, t, ctx)),
            (
                "group_monolithic",
                lambda du, s, t, ctx: replicate_group(
                    du, s, t, ctx, striped=False
                ),
            ),
            ("sequential", replicate_sequential),
        )
        results = {}
        for mode, fn in modes:
            mgr, src, targets, du = _setup(real, f"{mode}-{size}")
            t = fn(du, src, targets, mgr.ctx) / scale  # rescale to sim-GB
            assert all(p.has_du(du.id) for p in targets)
            results[mode] = t
            rows.append(
                emit(f"replication.{mode}.{size}GB", t * 1e6, f"T_R={t:.1f}s")
            )
            mgr.shutdown()
        rows.append(
            emit(
                f"replication.claim.group_beats_sequential.{size}GB",
                0.0,
                str(results["group"] < results["sequential"]),
            )
        )
        rows.append(
            emit(
                f"replication.claim.striped_beats_monolithic.{size}GB",
                0.0,
                str(results["group"] < results["group_monolithic"]),
            )
        )
    # inset: per-host T_X spread for the 4 GB case
    topo = make_grid_topology([(lbl, bw, 0.02) for lbl, bw in [SRC, *SITES]])
    txs = np.array(
        [estimate_tx(4 * GB, SRC[0], lbl, topo) for lbl, _ in SITES]
    )
    rows.append(
        emit(
            "replication.inset.per_host_tx_4GB",
            float(txs.mean() * 1e6),
            f"min={txs.min():.0f}s;max={txs.max():.0f}s;spread={txs.max()/txs.min():.1f}x",
        )
    )
    return rows


if __name__ == "__main__":
    run()
