"""Benchmark harness — one bench per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV (one row per measurement), and with
``--json PATH`` additionally writes the same rows machine-readably so CI
can archive a perf trajectory artifact per run.

  bench_staging      — Fig. 7 (T_S per storage backend × size)
  bench_replication  — Fig. 8 (T_R group vs sequential, per-host inset)
  bench_placement    — Figs. 9–10 (five placement strategies, 8-task BWA)
                       + placement-plugin sync/async equivalence
  bench_scale        — Figs. 11–13 (1024 tasks × 1–3 machines ± replication)
                       + async-vs-sync pipelined staging comparison
  bench_dataflow     — Pilot-API v2 DAG: one-shot declarative submission
                       (sync + async) vs v1 submit-wait-submit
  bench_streaming    — chunk-streaming shuffle vs seal-gated pipeline
                       (prefix-released consumers) + exactly-once rollback
  bench_faults       — makespan-under-churn: kill k of n pilots
                       mid-workload; replication-factor healing + lineage
                       recomputation; monitor op-count O(changes) proof
  bench_tiering      — storage hierarchy: mem-tier caching + quota
                       eviction vs flat re-staging for a working set
                       larger than DRAM; eviction-correctness claim
  bench_mlstack      — ML stack on the runtime: one-shot training DAG vs
                       submit-wait, tier-cached serving fleet cold-start,
                       checkpoint-chain survival under pilot kill, and a
                       per-model-config cold-start scenario sweep
  bench_store        — coordination-store write throughput: sharded
                       (striped locks + queued dispatch + group-commit
                       WAL) vs legacy single-lock mode, 1 and N writers
  bench_multitenant  — QoS under tenant contention: light-tenant p99
                       uncontended vs quota-fair vs unquota'd flood, plus
                       the tenant-aware-eviction pinned-set claim
  bench_cost_model   — §6.1 calculus vs oracle + replication degree
  bench_roofline     — assignment §Roofline terms from dry-run artifacts
"""

import argparse
import json
import platform
import sys
import traceback
from typing import Dict, List


def _row_to_json(row: str) -> Dict[str, object]:
    name, us, derived = row.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="shrink bench_scale")
    ap.add_argument("--only", default=None, help="run a single bench by name")
    ap.add_argument(
        "--json",
        default=None,
        metavar="PATH",
        help="also write rows as a JSON artifact (for CI perf trajectories)",
    )
    args = ap.parse_args()

    from . import (
        bench_cost_model,
        bench_dataflow,
        bench_faults,
        bench_mlstack,
        bench_multitenant,
        bench_placement,
        bench_replication,
        bench_roofline,
        bench_scale,
        bench_staging,
        bench_store,
        bench_streaming,
        bench_tiering,
    )

    benches = {
        "staging": lambda: bench_staging.run(),
        "replication": lambda: bench_replication.run(),
        "placement": lambda: bench_placement.run(),
        "scale": lambda: bench_scale.run(n_tasks=128 if args.quick else 1024),
        "dataflow": lambda: bench_dataflow.run(),
        "streaming": lambda: bench_streaming.run(),
        "faults": lambda: bench_faults.run(quick=args.quick),
        "tiering": lambda: bench_tiering.run(),
        "mlstack": lambda: bench_mlstack.run(quick=args.quick),
        "store": lambda: bench_store.run(),
        "multitenant": lambda: bench_multitenant.run(quick=args.quick),
        "cost_model": lambda: bench_cost_model.run(),
        "roofline": lambda: bench_roofline.run(),
    }
    if args.only and args.only not in benches:
        print(
            f"unknown bench {args.only!r} (known: {', '.join(benches)})",
            file=sys.stderr,
        )
        sys.exit(2)
    print("name,us_per_call,derived")
    all_rows: List[str] = []
    failed = []
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        try:
            all_rows.extend(fn() or [])
        except Exception as exc:  # noqa: BLE001
            failed.append(name)
            row = f"{name}.ERROR,0.0,{type(exc).__name__}:{exc}"
            print(row)
            all_rows.append(row)
            traceback.print_exc(file=sys.stderr)
    if args.json:
        payload = {
            "schema": "bench-rows/v1",
            "quick": args.quick,
            "only": args.only,
            "python": platform.python_version(),
            "rows": [_row_to_json(r) for r in all_rows],
            "failed": failed,
        }
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {len(all_rows)} rows to {args.json}", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
