"""Benchmark harness — one bench per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV (one row per measurement).

  bench_staging      — Fig. 7 (T_S per storage backend × size)
  bench_replication  — Fig. 8 (T_R group vs sequential, per-host inset)
  bench_placement    — Figs. 9–10 (five placement strategies, 8-task BWA)
  bench_scale        — Figs. 11–13 (1024 tasks × 1–3 machines ± replication)
  bench_cost_model   — §6.1 calculus vs oracle + replication degree
  bench_roofline     — assignment §Roofline terms from dry-run artifacts
"""

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="shrink bench_scale")
    ap.add_argument("--only", default=None, help="run a single bench by name")
    args = ap.parse_args()

    from . import (
        bench_cost_model,
        bench_placement,
        bench_replication,
        bench_roofline,
        bench_scale,
        bench_staging,
    )

    benches = {
        "staging": lambda: bench_staging.run(),
        "replication": lambda: bench_replication.run(),
        "placement": lambda: bench_placement.run(),
        "scale": lambda: bench_scale.run(n_tasks=128 if args.quick else 1024),
        "cost_model": lambda: bench_cost_model.run(),
        "roofline": lambda: bench_roofline.run(),
    }
    print("name,us_per_call,derived")
    failed = []
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        try:
            fn()
        except Exception as exc:  # noqa: BLE001
            failed.append(name)
            print(f"{name}.ERROR,0.0,{type(exc).__name__}:{exc}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
