"""Figs. 11–13 — "Large-Scale, Distributed Genome Sequencing on XSEDE":
a 1024-task ensemble (9 GB input each) across 1–3 machines, with and
without up-front Data-Unit replication.

Scenarios (paper numbering):
  1. Lonestar only — I/O contention on one machine (per-task slowdown
     grows with concurrency, the paper's Fig. 12 observation);
  2. + Stampede, NO replication — each remote task must move 9 GB first,
     so the remote machine wins few tasks (paper: ~5 %);
  3. + Stampede, WITH up-front replication — staging collapses to a link,
     distribution balances (paper: ~40 % remote) and T improves despite
     Stampede's 8100 s queue;
  4. + Trestles over WAN, with replication — more spread, but queue-time
     variance and the WAN hurt: T lands between scenarios 3 and 1.

Mechanics: Data-Units are staged/replicated through the REAL runtime (real
PDs, real replica state); task placement + makespan are then replayed with
a deterministic slot-level discrete-event scheduler driven by the §6.1
cost calculus — each free slot takes the next task wherever
(queue + staging + compute) finishes earliest, with staging cost 0 where a
replica is linkable and T_X otherwise.  (The threaded runtime executes
tasks in wall-time, which is instant here; sim-time load dynamics need the
event replay — DESIGN.md §2.)
"""

from __future__ import annotations

import gc
import heapq
import statistics
import time
from typing import Dict, List, Tuple

from repro.core import (
    CUState,
    FUNCTIONS,
    Session,
    Topology,
    estimate_tx,
    replicate_group,
)
from repro.core.coordination import CoordinationStore

from .common import GB, MB, Timer, emit

SCALE = 1e-4  # 100 KB stands in for 1 GB of DU payload
TASK_GB = 9.0
N_TASKS = 1024
BASE_COMPUTE_S = 3600.0
LONESTAR, STAMPEDE, TRESTLES = "xsede:lonestar", "xsede:stampede", "xsede:trestles"
QUEUE_S = {LONESTAR: 400.0, STAMPEDE: 8100.0, TRESTLES: 2500.0}
SLOTS = {LONESTAR: 512, STAMPEDE: 256, TRESTLES: 128}


def _topology() -> Topology:
    topo = Topology()
    topo.register(LONESTAR, bandwidth=40 * MB, latency=0.02)
    topo.register(STAMPEDE, bandwidth=40 * MB, latency=0.02)
    topo.register(TRESTLES, bandwidth=10 * MB, latency=0.08)
    return topo


def _io_stretch(concurrency: int) -> float:
    """Fig. 12: per-task runtime grows with concurrent tasks per machine
    (shared-filesystem contention)."""
    return 1.0 + 0.002 * concurrency


def _des_schedule(
    n_tasks: int,
    machines: List[str],
    stage_cost: Dict[str, float],
    n_slots: Dict[str, int],
    queue_s: Dict[str, float],
) -> Tuple[float, Dict[str, int]]:
    """Slot-level event replay: each task goes wherever it would FINISH
    earliest (queue wait + staging + contention-stretched compute).

    Remote staging (stage_cost > 0) SERIALIZES on the home machine's
    outbound uplink — concurrent 9 GB pulls share one link, which is what
    limited the paper's scenario 2 to ~5 % remote tasks."""
    per_machine = {m: [queue_s[m]] * n_slots[m] for m in machines}
    for m in machines:
        heapq.heapify(per_machine[m])
    split = {m: 0 for m in machines}
    uplink_free = 0.0
    end_times = []
    for _ in range(n_tasks):
        best = None
        for m in machines:
            t0 = per_machine[m][0]
            # contention from slots still busy at this task's start time —
            # waves with fewer concurrent tasks run faster (Fig. 12)
            busy = sum(1 for t in per_machine[m] if t > t0)
            stretch = _io_stretch(busy)
            if stage_cost[m] > 0:
                start = max(t0, uplink_free)
                fin = start + stage_cost[m] + BASE_COMPUTE_S * stretch
            else:
                fin = t0 + BASE_COMPUTE_S * stretch
            if best is None or fin < best[0]:
                best = (fin, m, t0)
        fin, m, t0 = best
        heapq.heappop(per_machine[m])
        heapq.heappush(per_machine[m], fin)
        if stage_cost[m] > 0:
            uplink_free = max(t0, uplink_free) + stage_cost[m]
        split[m] += 1
        end_times.append(fin)
    return max(end_times), split


def _run_scenario(
    tag: str, machines: List[str], replicate: bool, n_tasks: int
) -> Dict:
    sess = Session(topology=_topology())
    pds = {
        m: sess.start_pilot_data(service_url=f"mem://{m}/pd-{tag}", affinity=m)
        for m in machines
    }
    home = machines[0]
    nbytes_real = int(TASK_GB * GB * SCALE)
    # one representative DU carries the replica state (all task inputs
    # share placement in these scenarios); T_R measured on the real runtime
    du = sess.submit_du(
        name=f"inputs-{tag}",
        files={"reads.fq": b"R" * nbytes_real},
        target=pds[home],
    ).du
    # Quick mode shrinks the ensemble; the batch-queue waits must shrink
    # proportionally or they dwarf the smaller workload and the paper's
    # regime (queue time ≈ a few task waves) degenerates — at 128 tasks an
    # unscaled 8100 s Stampede queue outlasts the whole run, so replication
    # could never shift the split and the distribution claims went False
    # (the CHANGES.md PR 2 note).  Full runs (n_tasks = N_TASKS) keep the
    # paper's absolute queue times.
    queue_s = {m: QUEUE_S[m] * n_tasks / N_TASKS for m in machines}
    t_d = 0.0
    if replicate and len(machines) > 1:
        others = [pds[m] for m in machines[1:]]
        # T_R measured through the real replication machinery; the paper's
        # replication overlapped with the pilots' batch-queue wait
        # (scenario 3: "in average the creation of the replica takes 130
        # sec and is negligible"), so only the non-overlapped part counts.
        per_du = replicate_group(du, pds[home], others, sess.ctx) / SCALE
        t_d = max(0.0, per_du - min(queue_s[m] for m in machines[1:]))
    topo = sess.topology
    stage_cost = {}
    for m in machines:
        if pds[m].has_du(du.id):
            stage_cost[m] = 0.0  # linkable replica
        else:
            stage_cost[m] = estimate_tx(
                int(TASK_GB * GB), home, m, topo
            )
    # quick mode scales slot counts with the task count (same ratios)
    n_slots = {
        m: max(8, SLOTS[m] * n_tasks // N_TASKS) for m in machines
    }
    makespan, split = _des_schedule(
        n_tasks, machines, stage_cost, n_slots, queue_s
    )
    sess.close()
    return {"T": t_d + makespan, "split": split, "t_d": t_d, "stage": stage_cost}


def _serial_makespan(pairs: List[Tuple[float, float]], slots: int) -> float:
    """Sync agents: each slot pays stage + compute back-to-back."""
    heap = [0.0] * max(1, slots)
    heapq.heapify(heap)
    for s, c in pairs:
        t = heapq.heappop(heap)
        heapq.heappush(heap, t + s + c)
    return max(heap)


def _pipelined_makespan(pairs: List[Tuple[float, float]], slots: int) -> float:
    """Async scheduler: staging of task i+1 prefetches during task i's
    compute, so a slot's chain is s_0 + Σ max(c_i, s_{i+1}) + c_last —
    only the pipeline fill (first staging) and any staging longer than the
    preceding compute stay on the critical path."""
    lanes: List[List[Tuple[float, float]]] = [[] for _ in range(max(1, slots))]
    for i, pair in enumerate(pairs):
        lanes[i % max(1, slots)].append(pair)
    spans = []
    for lane in lanes:
        if not lane:
            continue
        t = lane[0][0]  # fill: first staging cannot overlap anything
        for j, (_, c) in enumerate(lane):
            nxt_stage = lane[j + 1][0] if j + 1 < len(lane) else 0.0
            t += max(c, nxt_stage)
        spans.append(t)
    return max(spans) if spans else 0.0


def _pipelining_comparison(rows: List[str], n_tasks: int) -> None:
    """Same real workload through both scheduler modes.

    Wall-clock: remote per-task DUs at SCALE'd sizes with ``time_scale``
    turning simulated staging/compute into real sleeps — the async mode's
    prefetch pool overlaps staging with execution, the sync agents cannot.
    Simulated makespan: replayed from the recorded per-CU (stage, compute)
    durations under both execution models.
    """
    n = min(n_tasks, 8)  # real execution: keep the wall-clock bench tight
    site_a, site_b = "xsede:lonestar", "xsede:stampede"
    stage_bytes = int(4 * MB)  # ~2 s simulated over the 2 MB/s WAN link
    compute_s = 1.0
    time_scale = 0.02
    results: Dict[str, Dict[str, float]] = {}
    for mode in ("sync", "async"):
        topo = Topology()
        topo.register(site_a, bandwidth=2 * MB, latency=0.05)
        topo.register(site_b, bandwidth=2 * MB, latency=0.05)
        sess = Session(
            topology=topo, scheduler_mode=mode, time_scale=time_scale
        )
        try:
            pd = sess.start_pilot_data(
                service_url=f"mem://{site_b}/pd-pipe-{mode}", affinity=site_b
            )
            pilot = sess.start_pilot(resource_url=f"sim://{site_a}", slots=1)
            pilot.wait_active()
            FUNCTIONS.register(f"pipe:{mode}", lambda cu_ctx: "ok")
            dus = [
                sess.submit_du(
                    name=f"pipe-{mode}-{i}",
                    files={f"part{i}": b"p" * stage_bytes},
                    target=pd,
                )
                for i in range(n)
            ]
            [du.wait() for du in dus]
            with Timer() as t:
                cus = [
                    sess.submit_cu(
                        executable=f"pipe:{mode}",
                        input_data=[dus[i]],
                        sim_compute_s=compute_s,
                    )
                    for i in range(n)
                ]
                assert sess.wait(timeout=120), f"{mode} run did not finish"
            for cu in cus:
                assert cu.state == CUState.DONE, (mode, cu.state, cu.error)
            pairs = [
                (
                    cu.timings.sim_stage_s + cu.timings.sim_prefetch_s,
                    cu.timings.sim_compute_s,
                )
                for cu in cus
            ]
            results[mode] = {"wall": t.wall, "pairs": pairs}
        finally:
            sess.close()
    sim_sync = _serial_makespan(results["sync"]["pairs"], slots=1)
    sim_async = _pipelined_makespan(results["async"]["pairs"], slots=1)
    wall_sync = results["sync"]["wall"]
    wall_async = results["async"]["wall"]
    rows.append(
        emit("scale.pipeline.sync_makespan_sim", sim_sync * 1e6, f"T={sim_sync:.1f}s")
    )
    rows.append(
        emit("scale.pipeline.async_makespan_sim", sim_async * 1e6, f"T={sim_async:.1f}s")
    )
    rows.append(
        emit("scale.pipeline.sync_wall_s", wall_sync * 1e6, f"{wall_sync:.3f}s")
    )
    rows.append(
        emit("scale.pipeline.async_wall_s", wall_async * 1e6, f"{wall_async:.3f}s")
    )
    rows.append(
        emit(
            "scale.claim.async_beats_sync_sim_makespan",
            0.0,
            f"{sim_async:.1f}<{sim_sync:.1f}:{sim_async < sim_sync}",
        )
    )
    rows.append(
        emit(
            "scale.claim.async_beats_sync_wallclock",
            0.0,
            f"{wall_async:.3f}<{wall_sync:.3f}:{wall_async < wall_sync}",
        )
    )


def coordination_cell(
    n_cus: int, n_pilots: int, repeats: int = 3
) -> Dict[str, float]:
    """Drive the canonical per-CU coordination-op sequence against a fresh
    sharded store with an agent-shaped subscriber population.

    Per CU: one push + pop on the pilot's queue, three ``cu:`` state
    transitions, one winner-CAS; every 100 CUs a monitor-style
    ``hkeys("pilot:")`` scan.  Each pilot contributes two prefix
    subscriptions (its ``pilot:``/``pd:`` watchers) plus plane-wide
    ``cu:``/``du:`` consumers — so the 100-pilot cell carries ~10× the
    subscriber table of the 10-pilot cell.  The claim: per-event cost
    stays flat as CUs × pilots scale 10×, i.e. the prefix-indexed
    subscription table, striped locks, and bisect scans hold the per-op
    cost constant.  Best-of-``repeats`` per-event µs; GC is paused during
    the timed loop so collector pauses — whose cost scales with the live
    heap, not with the store's per-op work — don't skew the large cell.
    """
    best_us = float("inf")
    delivered_expect = 4 * n_cus  # 3 state hsets + 1 winner CAS per CU
    for _ in range(repeats):
        store = CoordinationStore()
        delivered = [0]

        def _count(ev, _d=delivered) -> None:
            _d[0] += 1

        def _noop(ev) -> None:
            pass

        for p in range(n_pilots):
            store.subscribe(_noop, prefix=f"pilot:p{p}")
            store.subscribe(_noop, prefix=f"pd:sb{p}")
        store.subscribe(_count, prefix="cu:")  # scheduler-shaped consumer
        store.subscribe(_noop, prefix="du:")  # dependency-gate-shaped
        for p in range(n_pilots):
            store.hset(f"pilot:p{p}", "state", "Active")
        store.flush_events()
        ops_before = store.ops_total
        gc.collect()
        gc_was_enabled = gc.isenabled()
        gc.disable()
        try:
            t0 = time.perf_counter()
            for i in range(n_cus):
                q = f"queue:pilot:p{i % n_pilots}"
                store.push(q, {"cu": f"c{i}"})
                store.pop(q)
                key = f"cu:c{i}"
                store.hset(key, "state", "Pending")
                store.hset(key, "state", "Running")
                store.hcas(key, "winner", None, f"p{i % n_pilots}")
                store.hset(key, "state", "Done")
                if i % 100 == 99:
                    store.hkeys("pilot:")  # heartbeat-monitor range scan
            assert store.flush_events(timeout=60.0), "dispatcher fell behind"
            elapsed = time.perf_counter() - t0
        finally:
            if gc_was_enabled:
                gc.enable()
        ops = store.ops_total - ops_before
        assert delivered[0] == delivered_expect, (delivered[0], delivered_expect)
        store.close()
        best_us = min(best_us, elapsed / ops * 1e6)
    return {"per_event_us": best_us, "ops": ops, "delivered": delivered_expect}


def _coordination_scale(rows: List[str]) -> None:
    """The 10k-CU / 100-pilot cell vs the 1k-CU / 10-pilot cell.

    Interleaved repeats (small, large, small, large, …) with the median
    per cell: machine-load drift across the bench run biases both cells
    the same way, and the median absorbs one-off spikes in either
    direction (the 33 ms small cell is especially jumpy under load)."""
    coordination_cell(500, 10, repeats=1)  # warm-up: allocator + code paths
    small_us: List[float] = []
    large_us: List[float] = []
    for _ in range(7):
        s = coordination_cell(1_000, 10, repeats=1)
        g = coordination_cell(10_000, 100, repeats=1)
        small_us.append(s["per_event_us"])
        large_us.append(g["per_event_us"])
    small = {**s, "per_event_us": statistics.median(small_us)}
    large = {**g, "per_event_us": statistics.median(large_us)}
    rows.append(
        emit(
            "scale.coord.per_event_us_1k",
            small["per_event_us"],
            f"{small['ops']}ops/{small['delivered']}ev",
        )
    )
    rows.append(
        emit(
            "scale.coord.per_event_us_10k",
            large["per_event_us"],
            f"{large['ops']}ops/{large['delivered']}ev",
        )
    )
    ratio = large["per_event_us"] / max(small["per_event_us"], 1e-9)
    rows.append(
        emit(
            "scale.claim.coord_per_event_cost_flat_10k",
            0.0,
            f"ratio={ratio:.2f}:{0.8 <= ratio <= 1.2}",
        )
    )


def run(n_tasks: int = N_TASKS) -> List[str]:
    rows = []
    _coordination_scale(rows)
    _pipelining_comparison(rows, n_tasks)
    s1 = _run_scenario("s1", [LONESTAR], False, n_tasks)
    s2 = _run_scenario("s2", [LONESTAR, STAMPEDE], False, n_tasks)
    s3 = _run_scenario("s3", [LONESTAR, STAMPEDE], True, n_tasks)
    s4 = _run_scenario("s4", [LONESTAR, STAMPEDE, TRESTLES], True, n_tasks)
    for name, s in (("s1_single", s1), ("s2_two_norepl", s2),
                    ("s3_two_repl", s3), ("s4_three_wan_repl", s4)):
        rows.append(emit(f"scale.{name}.makespan", s["T"] * 1e6, f"T={s['T']:.0f}s"))
        rows.append(emit(f"scale.{name}.split", 0.0, str(s["split"])))
    remote2 = s2["split"].get(STAMPEDE, 0) / max(1, n_tasks)
    remote3 = s3["split"].get(STAMPEDE, 0) / max(1, n_tasks)
    rows.append(
        emit("scale.claim.repl_improves_distribution", 0.0,
             f"{remote2:.2f}->{remote3:.2f}:{remote3 > remote2}")
    )
    rows.append(
        emit("scale.claim.multi_machine_beats_single", 0.0, str(s3["T"] < s1["T"]))
    )
    rows.append(
        emit("scale.claim.wan_run_completes_and_spreads", 0.0,
             str(sum(1 for v in s4["split"].values() if v > 0) == 3))
    )
    return rows


if __name__ == "__main__":
    run()
