"""Dataflow DAG makespan: one-shot declarative submission vs v1-style
submit-wait-submit.

A 3-stage map → shuffle → reduce DAG whose every stage edge crosses a
2 MB/s WAN link (maps pinned to site A, shuffles to site B, reduce back to
A), run three ways over the SAME workload:

  sequential      — Pilot-API v1 pattern: submit a stage, block until it
                    completes, submit the next.  Stage barriers on the
                    user side; agents pay all staging in-slot.
  oneshot_sync    — whole DAG submitted upfront through a Session; the
                    DU-readiness gate sequences stages, so a consumer
                    starts the moment its producers seal (no stage-wide
                    barrier), but agents still stage in-slot.
  oneshot_async   — same one-shot DAG under the event-driven scheduler:
                    a released consumer's inputs are prefetched on the
                    staging pool, overlapping stage i+1's stage-in with
                    stage i's remaining execution across DAG edges.

Wall-clock rows use ``time_scale`` (simulated seconds become real sleeps);
the ``blocking_stage_sim`` rows are deterministic simulated seconds charged
on the CUs' critical paths and carry the overlap claim reproducibly.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import (
    CUState,
    DataUnitDescription,
    FUNCTIONS,
    Session,
    Topology,
)

from .common import MB, Timer, emit

SITE_A, SITE_B = "wan:sitea", "wan:siteb"
N_MAP = 4
#: 0.5 MB/s link → 2 s sim per 1 MB input, 1 s per 0.5 MB stage output;
#: small real payloads + a large time_scale keep the wall-clock rows
#: dominated by simulated (deterministic) durations, not scheduler noise
IN_BYTES = int(1 * MB)
MID_BYTES = int(0.5 * MB)
COMPUTE_S = 2.0
TIME_SCALE = 0.05


def _topology() -> Topology:
    topo = Topology()
    topo.register(SITE_A, bandwidth=0.5 * MB, latency=0.05)
    topo.register(SITE_B, bandwidth=0.5 * MB, latency=0.05)
    return topo


def _register(tag: str) -> None:
    def mapper(cu_ctx):
        du = cu_ctx.input_dus()[0]
        n = sum(len(cu_ctx.read_input(du.id, rel)) for rel in du.manifest)
        cu_ctx.write_output("m", b"M" * MID_BYTES)
        return n

    def shuffler(cu_ctx):
        n = 0
        for du in cu_ctx.input_dus():
            n += sum(len(cu_ctx.read_input(du.id, r)) for r in du.manifest)
        cu_ctx.write_output("s", b"S" * MID_BYTES)
        return n

    def reducer(cu_ctx):
        n = 0
        for du in cu_ctx.input_dus():
            n += sum(len(cu_ctx.read_input(du.id, r)) for r in du.manifest)
        return n

    FUNCTIONS.register(f"dfb-map:{tag}", mapper)
    FUNCTIONS.register(f"dfb-shuffle:{tag}", shuffler)
    FUNCTIONS.register(f"dfb-reduce:{tag}", reducer)


def _setup(tag: str, mode: str) -> tuple:
    sess = Session(
        topology=_topology(), scheduler_mode=mode, time_scale=TIME_SCALE
    )
    pd = sess.start_pilot_data(service_url=f"mem://{SITE_B}/pd-{tag}", affinity=SITE_B)
    pa = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=2)
    pb = sess.start_pilot(resource_url=f"sim://{SITE_B}", slots=2)
    pa.wait_active(), pb.wait_active()
    parts = [
        sess.submit_du(
            name=f"in-{tag}-{i}", files={"d": b"I" * IN_BYTES}, target=pd
        )
        for i in range(N_MAP)
    ]
    [p.wait() for p in parts]
    return sess, parts


def _stage_cus(sess, tag: str, stage: str, inputs: List, affinity: str):
    """One stage's CUs: each consumes ``inputs`` and produces one DU."""
    out = DataUnitDescription(name=f"{stage}-{tag}-out")
    return sess.submit_cu(
        executable=f"dfb-{stage}:{tag}",
        input_data=inputs,
        output_data=[out] if stage != "reduce" else [],
        affinity=affinity,
        sim_compute_s=COMPUTE_S,
    )


def _submit_dag(sess, tag: str, parts: List) -> tuple:
    """The whole 3-stage DAG, wired by object, zero user-side waits."""
    maps = [
        _stage_cus(sess, tag, "map", [p], SITE_A) for p in parts
    ]
    shuffles = [
        _stage_cus(
            sess, tag, "shuffle",
            [m.output for m in maps[i::2]], SITE_B,
        )
        for i in range(2)
    ]
    reduce_ = _stage_cus(
        sess, tag, "reduce", [sh.output for sh in shuffles], SITE_A
    )
    return maps, shuffles, reduce_


def _collect(sess, cus) -> Dict[str, float]:
    blocking = sum(cu.timings.sim_stage_s for cu in cus)
    prefetched = sum(cu.timings.sim_prefetch_s for cu in cus)
    for cu in cus:
        assert cu.state == CUState.DONE, (cu.id, cu.state, cu.error)
    return {"blocking": blocking, "prefetched": prefetched}


def _run_sequential(tag: str) -> Dict[str, float]:
    """v1 pattern: a stage is submitted only after the previous one is
    fully terminal (user-side barrier)."""
    _register(tag)
    sess, parts = _setup(tag, "sync")
    try:
        with Timer() as t:
            maps = [_stage_cus(sess, tag, "map", [p], SITE_A) for p in parts]
            assert sess.wait(timeout=240)
            shuffles = [
                _stage_cus(
                    sess, tag, "shuffle",
                    [m.output for m in maps[i::2]], SITE_B,
                )
                for i in range(2)
            ]
            assert sess.wait(timeout=240)
            reduce_ = _stage_cus(
                sess, tag, "reduce", [sh.output for sh in shuffles], SITE_A
            )
            assert reduce_.result(timeout=240) == 2 * MID_BYTES
        stats = _collect(sess, [*maps, *shuffles, reduce_])
        stats["wall"] = t.wall
        return stats
    finally:
        sess.close()


def _run_oneshot(tag: str, mode: str) -> Dict[str, float]:
    _register(tag)
    sess, parts = _setup(tag, mode)
    try:
        with Timer() as t:
            maps, shuffles, reduce_ = _submit_dag(sess, tag, parts)
            assert reduce_.result(timeout=240) == 2 * MID_BYTES
        stats = _collect(sess, [*maps, *shuffles, reduce_])
        stats["wall"] = t.wall
        return stats
    finally:
        sess.close()


def run() -> List[str]:
    rows: List[str] = []
    seq = _run_sequential("seq")
    one_sync = _run_oneshot("osync", "sync")
    one_async = _run_oneshot("oasync", "async")
    for name, r in (
        ("sequential_sync", seq),
        ("oneshot_sync", one_sync),
        ("oneshot_async", one_async),
    ):
        rows.append(
            emit(f"dataflow.{name}.wall_s", r["wall"] * 1e6, f"{r['wall']:.3f}s")
        )
        rows.append(
            emit(
                f"dataflow.{name}.blocking_stage_sim",
                r["blocking"] * 1e6,
                f"{r['blocking']:.1f} sim-s blocking "
                f"(+{r['prefetched']:.1f} overlapped)",
            )
        )
    rows.append(
        emit(
            "dataflow.claim.oneshot_async_beats_sequential_wall",
            0.0,
            f"{one_async['wall']:.3f}<{seq['wall']:.3f}:"
            f"{one_async['wall'] < seq['wall']}",
        )
    )
    rows.append(
        emit(
            "dataflow.claim.async_overlaps_cross_stage_staging",
            0.0,
            # blocking critical-path staging is the deterministic signal;
            # the prefetched total is informational (its store attribution
            # can race the agent's read and undercount)
            f"blocking {one_async['blocking']:.1f}<{seq['blocking']:.1f} "
            f"(prefetched~{one_async['prefetched']:.1f}):"
            f"{one_async['blocking'] < seq['blocking']}",
        )
    )
    rows.append(
        emit(
            "dataflow.claim.oneshot_not_slower_than_sequential",
            0.0,
            f"{one_sync['wall']:.3f} vs {seq['wall']:.3f}:"
            f"{one_sync['wall'] < seq['wall'] * 1.1}",
        )
    )
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for _ in run():
        pass
