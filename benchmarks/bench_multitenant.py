"""Multi-tenant QoS benchmark: 1 flooding heavy tenant + N light tenants.

Three runs of the same light workload (one short CU per light tenant on a
shared 3-pilot farm), replayed on the simulated transfer/compute clock:

  uncontended — the light tenants have the farm to themselves: the
                baseline per-CU latency.
  fair        — a heavy tenant floods HEAVY_N short CUs first, but is
                registered with a ``cu_slots`` admission quota: surplus
                work parks in the AdmissionController and drip-feeds as
                earlier CUs finish, so the shared queue stays shallow.
  flood       — the same flood with NO quota (informational contrast):
                every heavy CU is admitted instantly and the light tenants
                queue behind the whole backlog.

Per-light-CU latency is replayed from the recorded schedule: the sum of
simulated durations of same-pilot CUs that started between the light CU's
submission and its own start, plus its own simulated duration — i.e. the
queue wait it actually experienced on its 1-slot pilot, on the virtual
clock.  The CI-gated claim is the tentpole acceptance bound: light p99
under the quota-fair flood stays within 1.5x the uncontended p99.

A second mini-scenario exercises tenant-aware eviction: a rival tenant
fills a shared edge PD and requests room while another tenant's pinned
working set lives there — evictions must happen (the requestor's own and
unpinned redundant chunks) yet never touch the pinned replica.  Emitted as
a claim row, gated like the recovery-path claims.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

from repro.core import (
    CoordinationStore,
    CUState,
    DataUnit,
    DataUnitDescription,
    FUNCTIONS,
    PilotData,
    PilotDataDescription,
    PilotManager,
    ResourceQuota,
    RuntimeContext,
    Session,
    TierManager,
    Topology,
    TransferService,
)

from .common import Timer, emit, modeled_makespan

SITE = "mt:site0"
N_PILOTS = 3
N_LIGHT = 3
LIGHT_SIM = 0.5
HEAVY_SIM = 0.05
HEAVY_QUOTA_SLOTS = 2
TIME_SCALE = 0.05  # real sleep per simulated second: keeps ordering honest

CHUNK = 16 * 1024
DU_BYTES = 4 * CHUNK


def _topology() -> Topology:
    topo = Topology()
    topo.register(SITE, bandwidth=30e6, latency=0.01)
    return topo


def _noop(cu_ctx):
    return "ok"


def _run_contention(
    n_heavy: int, heavy_quota: Optional[int]
) -> Dict[str, object]:
    FUNCTIONS.register("mt-bench-noop", _noop)
    mgr = PilotManager(topology=_topology(), time_scale=TIME_SCALE)
    try:
        pilots = [
            mgr.start_pilot(resource_url=f"sim://{SITE}/p{i}", slots=1)
            for i in range(N_PILOTS)
        ]
        for p in pilots:
            p.wait_active()
        heavies = []
        if n_heavy:
            quota = (
                ResourceQuota(cu_slots=heavy_quota) if heavy_quota else None
            )
            heavy = Session(manager=mgr, tenant="heavy", quota=quota)
            heavies = [
                heavy.submit_cu(
                    executable="mt-bench-noop", sim_compute_s=HEAVY_SIM
                )
                for _ in range(n_heavy)
            ]
        lights, submit_wall = [], []
        light_sessions = [
            Session(manager=mgr, tenant=f"light{i}") for i in range(N_LIGHT)
        ]
        for ls in light_sessions:
            submit_wall.append(time.monotonic())
            lights.append(
                ls.submit_cu(
                    executable="mt-bench-noop", sim_compute_s=LIGHT_SIM
                )
            )
        with Timer() as t:
            done = mgr.wait(timeout=300)
        assert done, "workload did not drain"
        every = heavies + lights
        assert all(c.state == CUState.DONE for c in every)

        def sim_of(fut) -> float:
            tm = mgr.store.hget(f"cu:{fut.id}", "timings") or {}
            return tm.get("sim_stage_s", 0.0) + tm.get("sim_compute_s", 0.0)

        # replay each light CU's latency from the recorded schedule
        latencies: List[float] = []
        for wall, lf in zip(submit_wall, lights):
            mine = lf.timings.run_start
            waited = sum(
                sim_of(o)
                for o in every
                if o.id != lf.id
                and o.pilot_id == lf.pilot_id
                and wall <= o.timings.run_start < mine
            )
            latencies.append(waited + sim_of(lf))
        makespan = modeled_makespan([sim_of(c) for c in every], N_PILOTS)
        adm = mgr.cds.admission
        return {
            "latencies": latencies,
            "p99": max(latencies),
            "makespan": makespan,
            "parked_total": adm.parked_total,
            "wall": t.wall,
        }
    finally:
        mgr.shutdown()


def _run_eviction_scenario() -> Dict[str, object]:
    ctx = RuntimeContext(store=CoordinationStore(), topology=_topology())
    TransferService(ctx)
    tm = TierManager(ctx, auto_promote=False)
    base = ctx.register(
        PilotData(
            PilotDataDescription(
                service_url=f"sharedfs://{SITE}/base", affinity=SITE
            ),
            ctx,
        )
    )
    edge = ctx.register(
        PilotData(
            PilotDataDescription(
                service_url=f"mem://{SITE}/edge", affinity=SITE
            ),
            ctx,
        )
    )

    def mk_du(name: str, tenant: str) -> DataUnit:
        du = DataUnit(
            DataUnitDescription(
                name=name,
                files={"x": name[:1].encode() * DU_BYTES},
                chunk_size=CHUNK,
                tenant=tenant,
            ),
            ctx.store,
        )
        return ctx.register(du)

    own = [mk_du(f"own{i}", "alpha") for i in range(2)]
    pinned = mk_du("pinned", "beta")
    loose = mk_du("loose", "beta")
    for du in [*own, pinned, loose]:
        base.put_du(du)
        edge.copy_du_from(du, base)
    ctx.store.hset("cu:beta-live", "state", CUState.RUNNING)
    tm.pins.pin(pinned.id, "beta-live")
    # alpha asks for more than its own redundant bytes: its replicas go
    # first, then beta's UNPINNED one — never the pinned working set
    freed = tm.make_room(edge, 3 * DU_BYTES, tenant="alpha")
    result = {
        "freed": freed,
        "evictions": len(tm.evictions),
        "cross": tm.cross_tenant_evictions_total,
        "cross_pinned": tm.cross_tenant_pinned_evictions,
        "pinned_intact": (
            pinned.id in edge.du_ids() and pinned.has_full_coverage()
        ),
    }
    tm.stop()
    return result


def run(quick: bool = False) -> List[str]:
    rows: List[str] = []
    n_heavy = 18 if quick else 48
    base = _run_contention(n_heavy=0, heavy_quota=None)
    fair = _run_contention(n_heavy=n_heavy, heavy_quota=HEAVY_QUOTA_SLOTS)
    flood = _run_contention(n_heavy=n_heavy, heavy_quota=None)

    rows.append(
        emit(
            "multitenant.light.uncontended.p99_latency",
            base["p99"] * 1e6,
            f"p99={base['p99']:.2f}s",
        )
    )
    rows.append(
        emit(
            "multitenant.light.fair.p99_latency",
            fair["p99"] * 1e6,
            f"p99={fair['p99']:.2f}s;parked={fair['parked_total']}",
        )
    )
    rows.append(
        emit(
            "multitenant.light.flood.p99_latency",
            flood["p99"] * 1e6,
            f"p99={flood['p99']:.2f}s;no-quota contrast",
        )
    )
    rows.append(
        emit(
            "multitenant.fair.makespan",
            fair["makespan"] * 1e6,
            f"T={fair['makespan']:.2f}s;n={n_heavy}+{N_LIGHT}",
        )
    )
    bound = 1.5 * base["p99"]
    ok = fair["p99"] <= bound
    rows.append(
        emit(
            "multitenant.claim.light_p99_bound",
            fair["p99"] * 1e6,
            f"{fair['p99']:.2f}s<=1.5x{base['p99']:.2f}s:{ok}",
        )
    )
    # admission really gated the heavy tenant in the fair run
    gated = fair["parked_total"] >= n_heavy - HEAVY_QUOTA_SLOTS
    rows.append(
        emit(
            "multitenant.claim.heavy_backlog_parked",
            float(fair["parked_total"]),
            f"parked={fair['parked_total']}>={n_heavy - HEAVY_QUOTA_SLOTS}"
            f":{gated}",
        )
    )
    ev = _run_eviction_scenario()
    ev_ok = (
        ev["evictions"] > 0
        and ev["cross"] >= 1
        and ev["cross_pinned"] == 0
        and ev["pinned_intact"]
    )
    rows.append(
        emit(
            "multitenant.claim.no_cross_tenant_pinned_eviction",
            float(ev["evictions"]),
            f"evictions={ev['evictions']};cross={ev['cross']};"
            f"cross_pinned={ev['cross_pinned']};"
            f"pinned_intact={ev['pinned_intact']}:{ev_ok}",
        )
    )
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(quick=True)
