"""Tiered-storage benchmark: memory-tier caching vs flat re-staging for a
working set larger than DRAM.

An iterative read workload (EPOCHS passes over N_DUS inputs) runs on one
pilot whose DRAM sandbox holds only a fraction of the working set, with
the inputs homed on a site-shared PD one WAN hop away — the RAM/remote-FS
split of "Hadoop on HPC" (Luckow et al., 2016) scaled down to the
simulated transfer clock.

  cached    — the tiered path: chunk-granular sandbox caching under quota
              eviction (LRU), plus hot-DU promotion into a mem-tier cache
              PD at the compute site (drained between epochs so the run is
              deterministic).  Steady-state epochs serve the cached share
              of the working set via zero-cost logical links.
  uncached  — the paper's PD-less naive mode (``cache_inputs=False``):
              every CU re-stages its full input from the cold tier.

Emitted rows gate in CI via check_regression: both makespans, the strict
cached < uncached claim, and an eviction-correctness claim (churn really
happened, yet no DU lost a chunk, every replica verifies, and every PD
respects its quota).
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import FUNCTIONS, DUState, Session, Topology

from .common import Timer, emit

N_DUS = 8
EPOCHS = 4
DU_BYTES = 256 * 1024
CHUNK_BYTES = 32 * 1024
SANDBOX_QUOTA = 2 * DU_BYTES  # DRAM tier holds 1/4 of the working set
CACHE_QUOTA = 4 * DU_BYTES  # site cache holds 1/2 of the working set
CU_SIM_S = 0.05
WAN_BW = 10e6  # bytes/s between the compute site and the cold site


def _topology() -> Topology:
    topo = Topology()
    topo.register("tier:site0", bandwidth=WAN_BW, latency=0.01)
    topo.register("tier:site1", bandwidth=WAN_BW, latency=0.01)
    return topo


def _run_workload(tag: str, cached: bool) -> Dict[str, object]:
    FUNCTIONS.register(
        f"bt-read:{tag}",
        lambda cu_ctx: sum(
            len(cu_ctx.read_input(du.id, "x")) for du in cu_ctx.input_dus()
        ),
    )
    sess = Session(
        topology=_topology(),
        eviction_policy="lru",
        tier_cache_bytes=CACHE_QUOTA if cached else 0,
        tier_auto_promote=False,  # drained between epochs: deterministic
    )
    try:
        cold = sess.start_pilot_data(
            service_url="sharedfs://tier:site1/cold", affinity="tier:site1"
        )
        pilot = sess.start_pilot(
            resource_url="sim://tier:site0",
            slots=1,
            sandbox_quota=SANDBOX_QUOTA,
        )
        pilot.wait_active()
        dus = [
            sess.submit_du(
                name=f"in-{tag}-{i}",
                files={"x": bytes([i]) * DU_BYTES},
                chunk_size=CHUNK_BYTES,
                target=cold,
            ).result()
            for i in range(N_DUS)
        ]
        tm = sess.tier_manager
        cu_sims: List[float] = []
        hits = 0
        with Timer() as t:
            for _epoch in range(EPOCHS):
                for du in dus:
                    cu = sess.submit_cu(
                        executable=f"bt-read:{tag}",
                        input_data=[du],
                        pilot=pilot,
                        sim_compute_s=CU_SIM_S,
                        cache_inputs=cached,
                    )
                    assert cu.result(timeout=30) == DU_BYTES
                    timings = sess.store.hget(f"cu:{cu.id}", "timings") or {}
                    stage = timings.get("sim_stage_s", 0.0)
                    cu_sims.append(stage + timings.get("sim_compute_s", 0.0))
                    if cached and stage == 0.0:
                        hits += 1
                if cached:
                    tm.drain_promotions()
        # one pilot slot: the modeled makespan is the serial sim total
        makespan = sum(cu_sims)
        pds = [cold, pilot.sandbox, *tm.cache_pds.values()]
        quota_ok = all(pd.used_bytes <= pd.description.size_quota for pd in pds)
        intact = all(
            du.state == DUState.READY
            and du.has_full_coverage()
            and cold.verify_du(du)
            for du in dus
        )
        return {
            "makespan": makespan,
            "wall": t.wall,
            "hits": hits,
            "n_cus": N_DUS * EPOCHS,
            "evictions": tm.evictions_total,
            "promotions": tm.promotions_total,
            "quota_ok": quota_ok,
            "intact": intact,
        }
    finally:
        sess.close()


def run() -> List[str]:
    rows: List[str] = []
    cached = _run_workload("cache", cached=True)
    uncached = _run_workload("nocache", cached=False)
    rows.append(
        emit(
            "tiering.cached.makespan",
            cached["makespan"] * 1e6,
            f"T={cached['makespan']:.2f}s",
        )
    )
    rows.append(
        emit(
            "tiering.uncached.makespan",
            uncached["makespan"] * 1e6,
            f"T={uncached['makespan']:.2f}s",
        )
    )
    ratio = cached["hits"] / cached["n_cus"]
    rows.append(
        emit(
            "tiering.cached.cache_hit_ratio",
            ratio * 100.0,
            f"{cached['hits']}/{cached['n_cus']}={ratio:.2f}",
        )
    )
    rows.append(
        emit(
            "tiering.cached.eviction_churn",
            float(cached["evictions"]),
            f"evictions={cached['evictions']};"
            f"promotions={cached['promotions']}",
        )
    )
    speedup = uncached["makespan"] / max(cached["makespan"], 1e-9)
    rows.append(
        emit(
            "tiering.claim.cached_beats_uncached",
            0.0,
            f"{cached['makespan']:.2f}<{uncached['makespan']:.2f}"
            f"({speedup:.2f}x):"
            f"{cached['makespan'] < uncached['makespan']}",
        )
    )
    churn_ok = (
        cached["evictions"] > 0
        and cached["promotions"] > 0
        and cached["quota_ok"]
        and cached["intact"]
    )
    rows.append(
        emit(
            "tiering.claim.eviction_correctness",
            0.0,
            f"evictions={cached['evictions']};quota_ok={cached['quota_ok']};"
            f"intact={cached['intact']}:{churn_ok}",
        )
    )
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for _ in run():
        pass
