"""Makespan-under-churn: kill pilots mid-workload and measure recovery.

Three measurements over the self-healing data layer (FaultManager +
ReplicaManager + lineage recomputation):

  churn_f2    — 18 CUs over 6 input DUs with ``replication_factor=2`` on
                3 pilots; 1 pilot is killed after completing 2 CUs.  The
                claim: no DU is lost (the surviving replicas keep every DU
                READY) and the workload completes with *bounded* slowdown
                vs the no-failure baseline (< 2x; losing 1 of 3 pilots
                re-list-schedules the dead pilot's work over the 2
                survivors).  Makespans are modeled from the recorded
                per-CU simulated (stage + compute) durations with the same
                m-slot list scheduler the other benches use, so the rows
                are deterministic and CI-gateable.
  lineage_f1  — a 2-stage DAG at ``replication_factor=1`` whose
                intermediate DU lives only in the killed pilot's sandbox
                (local buffer dropped): lineage recomputation re-runs the
                recorded producer and the DAG still completes.
  monitor ops — coordination-store op count per HeartbeatMonitor /
                StragglerMitigator tick is O(changes), not O(keyspace):
                a quiet tick costs 1 op (one heartbeat-hash scan) / 0 ops
                regardless of pilot/CU count.
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core import (
    ComputeUnit,
    ComputeUnitDescription,
    CoordinationStore,
    CUState,
    DataUnitDescription,
    DUState,
    FUNCTIONS,
    HeartbeatMonitor,
    PilotState,
    RuntimeContext,
    Session,
    StragglerMitigator,
    Topology,
)
from repro.core.pilot import HEARTBEATS_KEY

from .common import MB, Timer, emit, modeled_makespan

N_SITES = 3
N_DUS = 6
N_CUS = 18
CU_SIM_S = 100.0
DU_BYTES = 128 * 1024
KILL_AFTER_DONE = 2  # kill the victim once it completed this many CUs
TIME_SCALE = 0.0015  # 100 sim-s compute -> 0.15 wall-s per CU


def _topology() -> Topology:
    topo = Topology()
    for i in range(N_SITES):
        topo.register(f"churn:site{i}", bandwidth=10 * MB, latency=0.01)
    return topo


def _wait_until(pred, timeout=30.0, interval=0.002) -> bool:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return pred()


# ------------------------------------------------------------ churn (f=2)
def _run_churn(tag: str, kill: bool) -> Dict[str, object]:
    FUNCTIONS.register(f"bf-read:{tag}", lambda cu_ctx: 1)
    sess = Session(
        topology=_topology(),
        enable_fault_manager=True,
        heartbeat_timeout_s=0.3,
        time_scale=TIME_SCALE,
    )
    try:
        for i in range(2):  # replica homes in two failure domains
            sess.start_pilot_data(
                service_url=f"sharedfs://churn:site{i}/pd-{tag}",
                affinity=f"churn:site{i}",
            )
        pilots = [
            sess.start_pilot(resource_url=f"sim://churn:site{i}", slots=1)
            for i in range(N_SITES)
        ]
        for p in pilots:
            p.wait_active()
        dus = [
            sess.submit_du(
                name=f"in-{tag}-{i}",
                files={"d": b"D" * DU_BYTES},
                replication_factor=2,
            )
            for i in range(N_DUS)
        ]
        for d in dus:
            d.wait()
        # factor enforcement settles before the workload starts
        assert _wait_until(
            lambda: all(len(d.locations) >= 2 for d in dus), timeout=20
        ), "replication_factor=2 not enforced at submission"
        victim = pilots[-1]
        with Timer() as t:
            cus = [
                sess.submit_cu(
                    executable=f"bf-read:{tag}",
                    input_data=[dus[i % N_DUS]],
                    pilot=pilots[i % N_SITES],
                    sim_compute_s=CU_SIM_S,
                    max_retries=3,
                )
                for i in range(N_CUS)
            ]
            if kill:
                store = sess.store

                def victim_done() -> int:
                    return sum(
                        1 for cu in cus
                        if store.hget(f"cu:{cu.id}", "winner") == victim.id
                    )

                assert _wait_until(
                    lambda: victim_done() >= KILL_AFTER_DONE, timeout=30
                ), "victim never completed its pre-kill quota"
                victim.fail()
            assert sess.wait(timeout=120), "workload did not complete"
        for cu in cus:
            assert cu.state == CUState.DONE, (cu.id, cu.state, cu.error)
        # modeled makespan replay (deterministic): per-CU simulated
        # durations onto the slots that actually survived
        durations: Dict[str, float] = {}
        winners: Dict[str, str] = {}
        for cu in cus:
            timings = sess.store.hget(f"cu:{cu.id}", "timings") or {}
            durations[cu.id] = (
                timings.get("sim_stage_s", 0.0)
                + timings.get("sim_compute_s", 0.0)
            )
            winners[cu.id] = sess.store.hget(f"cu:{cu.id}", "winner")
        if kill:
            victim_load = sum(
                d for cu_id, d in durations.items()
                if winners[cu_id] == victim.id
            )
            survivor_work = [
                d for cu_id, d in durations.items()
                if winners[cu_id] != victim.id
            ]
            makespan = max(
                victim_load, modeled_makespan(survivor_work, N_SITES - 1)
            )
        else:
            makespan = modeled_makespan(list(durations.values()), N_SITES)
        lost = [
            d.id for d in dus
            if d.state != DUState.READY or not d.locations
        ]
        below_factor = [d.id for d in dus if len(d.locations) < 2]
        return {
            "makespan": makespan,
            "wall": t.wall,
            "lost": lost,
            "below_factor": below_factor,
            "victim_wins": sum(
                1 for w in winners.values() if w == victim.id
            ) if kill else 0,
        }
    finally:
        sess.close()


# ---------------------------------------------------------- lineage (f=1)
def _run_lineage(tag: str) -> Dict[str, object]:
    runs: List[int] = []

    def produce(cu_ctx):
        runs.append(1)
        du = cu_ctx.input_dus()[0]
        cu_ctx.write_output("y", cu_ctx.read_input(du.id, "src"))
        return len(runs)

    def consume(cu_ctx):
        du = cu_ctx.input_dus()[0]
        return len(cu_ctx.read_input(du.id, "y"))

    FUNCTIONS.register(f"bf-produce:{tag}", produce)
    FUNCTIONS.register(f"bf-consume:{tag}", consume)
    sess = Session(
        topology=_topology(),
        enable_fault_manager=True,
        heartbeat_timeout_s=0.3,
        time_scale=TIME_SCALE,
    )
    try:
        p0 = sess.start_pilot(resource_url="sim://churn:site0", slots=1)
        p1 = sess.start_pilot(resource_url="sim://churn:site1", slots=1)
        p0.wait_active(), p1.wait_active()
        src = sess.submit_du(
            name=f"src-{tag}", files={"src": b"S" * DU_BYTES}
        )
        with Timer() as t:
            prod = sess.submit_cu(
                executable=f"bf-produce:{tag}",
                input_data=[src],
                output_data=[DataUnitDescription(name=f"inter-{tag}")],
                pilot=p0,
                sim_compute_s=CU_SIM_S / 2,
            )
            inter = prod.output
            prod.result(timeout=30)
            inter_du = inter.result(timeout=10)
            # intermediate lives ONLY in p0's sandbox: factor=1, no buffer
            inter_du.drop_local_buffer()
            p0.fail()
            assert _wait_until(lambda: inter.recovering, timeout=20), (
                "lost DU never surfaced RECOVERING"
            )
            cons = sess.submit_cu(
                executable=f"bf-consume:{tag}",
                input_data=[inter],
                sim_compute_s=CU_SIM_S / 2,
            )
            n = cons.result(timeout=60)
        assert n == DU_BYTES
        # deterministic simulated critical path: producer, its recompute,
        # then the consumer
        sims = []
        for cu in (prod, cons):
            timings = sess.store.hget(f"cu:{cu.id}", "timings") or {}
            sims.append(
                timings.get("sim_stage_s", 0.0)
                + timings.get("sim_compute_s", 0.0)
            )
        makespan = sims[0] * 2 + sims[1]
        return {
            "makespan": makespan,
            "wall": t.wall,
            "producer_runs": len(runs),
            "recomputed": prod.id in sess.fault_manager.recomputed,
        }
    finally:
        sess.close()


# --------------------------------------------------------- monitor ops/tick
def _monitor_ops() -> Dict[str, float]:
    store = CoordinationStore()
    ctx = RuntimeContext(store=store, topology=Topology())
    now = time.monotonic()

    def add_pilots(lo: int, hi: int) -> None:
        for i in range(lo, hi):
            store.hset(f"pilot:p{i}", "state", PilotState.ACTIVE)
            store.hset(HEARTBEATS_KEY, f"p{i}", now)

    add_pilots(0, 50)
    mon = HeartbeatMonitor(ctx, timeout_s=60.0, suspect_timeout_s=30.0)
    before = store.ops_total
    mon._tick(now=now)
    hb_quiet_50 = store.ops_total - before
    add_pilots(50, 200)
    before = store.ops_total
    mon._tick(now=now)
    hb_quiet_200 = store.ops_total - before
    # 10 pilots go silent: ops grow by the number of *changes*
    for i in range(10):
        store.hset(HEARTBEATS_KEY, f"p{i}", now - 45.0)
    before = store.ops_total
    mon._tick(now=now)
    hb_changes_10 = store.ops_total - before
    mon.stop()

    mit = StragglerMitigator(ctx, min_samples=1)
    for i in range(200):
        cu = ComputeUnit(
            ComputeUnitDescription(executable="x"), store
        )
        ctx.register(cu)
        store.hset(f"cu:{cu.id}", "state", CUState.RUNNING)
    store.hset("cu:sample", "timings", {"t_c": 1e6})
    before = store.ops_total
    mit._tick()
    straggler_quiet_200 = store.ops_total - before
    mit.stop()
    return {
        "hb_quiet_50": hb_quiet_50,
        "hb_quiet_200": hb_quiet_200,
        "hb_changes_10": hb_changes_10,
        "straggler_quiet_200": straggler_quiet_200,
    }


def run(quick: bool = True) -> List[str]:
    rows: List[str] = []
    base = _run_churn("base", kill=False)
    churn = _run_churn("kill", kill=True)
    rows.append(
        emit(
            "faults.churn_f2.baseline.makespan",
            base["makespan"] * 1e6,
            f"T={base['makespan']:.0f}s",
        )
    )
    rows.append(
        emit(
            "faults.churn_f2.makespan",
            churn["makespan"] * 1e6,
            f"T={churn['makespan']:.0f}s;victim_wins={churn['victim_wins']}",
        )
    )
    rows.append(
        emit(
            "faults.churn_f2.wall_s",
            churn["wall"] * 1e6,
            f"{churn['wall']:.2f}s (baseline {base['wall']:.2f}s)",
        )
    )
    rows.append(
        emit(
            "faults.claim.churn_f2_no_du_lost",
            0.0,
            f"lost={churn['lost']};below_factor={churn['below_factor']}:"
            f"{not churn['lost'] and not churn['below_factor']}",
        )
    )
    slowdown = churn["makespan"] / max(base["makespan"], 1e-9)
    rows.append(
        emit(
            "faults.claim.churn_f2_bounded_slowdown",
            0.0,
            f"{churn['makespan']:.0f}<=2x{base['makespan']:.0f}"
            f"({slowdown:.2f}x):{slowdown <= 2.0}",
        )
    )

    lineage = _run_lineage("lin")
    rows.append(
        emit(
            "faults.lineage_f1.makespan",
            lineage["makespan"] * 1e6,
            f"T={lineage['makespan']:.0f}s",
        )
    )
    rows.append(
        emit(
            "faults.claim.lineage_f1_dag_completes",
            0.0,
            f"producer_runs={lineage['producer_runs']};"
            f"recomputed={lineage['recomputed']}:"
            f"{lineage['producer_runs'] == 2 and lineage['recomputed']}",
        )
    )

    ops = _monitor_ops()
    rows.append(
        emit(
            "faults.monitor.hb_ops_per_quiet_tick",
            ops["hb_quiet_200"],
            f"50 pilots:{ops['hb_quiet_50']} ops;"
            f"200 pilots:{ops['hb_quiet_200']} ops",
        )
    )
    rows.append(
        emit(
            "faults.monitor.hb_ops_per_tick_10_changes",
            ops["hb_changes_10"],
            f"{ops['hb_changes_10']} ops for 10 suspect transitions",
        )
    )
    rows.append(
        emit(
            "faults.claim.monitor_ops_o_changes",
            0.0,
            f"quiet {ops['hb_quiet_50']}=={ops['hb_quiet_200']} (O(1) in "
            f"keyspace), 10 changes -> {ops['hb_changes_10']} ops:"
            f"{ops['hb_quiet_50'] == ops['hb_quiet_200'] == 1 and ops['hb_changes_10'] <= 1 + 2 * 10}",
        )
    )
    rows.append(
        emit(
            "faults.claim.straggler_quiet_tick_zero_ops",
            0.0,
            f"200 RUNNING CUs, quiet tick: {ops['straggler_quiet_200']} "
            f"store ops:{ops['straggler_quiet_200'] == 0}",
        )
    )
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for _ in run():
        pass
