"""Streaming vs seal-gated map → shuffle → reduce critical path.

The same 2-mapper × 2-reducer shuffle workload runs twice over a WAN
topology, with identical per-record production pacing and per-chunk
consumption pacing (simulated clock):

  sealed     — intermediates are ordinary DUs: every reducer parks until
               its producers SEAL, so the reduce stage's consumption
               serializes entirely behind the map stage.
  streaming  — intermediates are streaming DUs (``ready_chunks`` window):
               mappers publish chunk prefixes per record flush, reducers
               are released on the first window and consume concurrently
               with production — map and reduce overlap on the critical
               path.

Both pipelines decode the identical record set (integrity asserted), so
the wall-clock difference is pure pipeline overlap.  The CI-gated claims:
the streaming run beats the sealed run strictly, and a producer attempt
that crashes mid-stream leaves zero chunks behind (its retry's content,
and only it, survives — exactly-once for streamed bytes).
"""

from __future__ import annotations

from typing import List

from repro.core import (
    DataUnitDescription,
    FUNCTIONS,
    Session,
    Topology,
)
from repro.data import RecordAssembler, encode_record

from .common import MB, Timer, emit

SITE_A, SITE_B = "wan:sitea", "wan:siteb"
N_MAP = 2
N_RED = 2
N_RECORDS = 8  # per mapper, alternating partitions (4 per reducer stream)
CHUNK = 2048
VALUE_BYTES = 2048  # one record ≈ one chunk of stream payload
MAP_REC_S = 1.0  # simulated production cost per record
RED_CHUNK_S = 1.0  # simulated consumption cost per stream chunk
WINDOW = 1  # reducer release threshold (chunks)
TIME_SCALE = 0.05


def _topology() -> Topology:
    topo = Topology()
    topo.register(SITE_A, bandwidth=0.5 * MB, latency=0.05)
    topo.register(SITE_B, bandwidth=0.5 * MB, latency=0.05)
    return topo


def _register(tag: str, streaming: bool) -> None:
    def mapper(cu_ctx, m):
        for i in range(N_RECORDS):
            r = i % N_RED
            cu_ctx.ctx.sleep_sim(MAP_REC_S)  # paced production
            cu_ctx.write_output(
                f"rec-{i:04d}",
                encode_record(f"k{m}-{i}", bytes([m]) * VALUE_BYTES),
                index=r,
            )
            if streaming and not cu_ctx.flush_output(r):
                return -1  # lost the stream to a foreign attempt
        return N_RECORDS

    def reducer_stream(cu_ctx):
        # round-robin over the live input streams: consumption tracks
        # whichever producer has chunks ready instead of serializing one
        # stream behind the other's seal
        nrec = 0
        its = {
            du_id: cu_ctx.stream_input(du_id, window=WINDOW)
            for du_id in cu_ctx.cu.description.input_data
        }
        asms = {du_id: RecordAssembler() for du_id in its}
        while its:
            for du_id in list(its):
                try:
                    _idx, chunk = next(its[du_id])
                except StopIteration:
                    assert asms[du_id].pending == 0
                    del its[du_id]
                    continue
                cu_ctx.ctx.sleep_sim(RED_CHUNK_S)  # paced consumption
                nrec += len(asms[du_id].feed(chunk))
        return nrec

    def reducer_sealed(cu_ctx):
        nrec = 0
        for du in cu_ctx.input_dus():
            cu_ctx.ctx.sleep_sim(RED_CHUNK_S * du.n_chunks)  # same pacing
            asm = RecordAssembler()
            for rel in sorted(du.manifest):
                nrec += len(asm.feed(cu_ctx.read_input(du.id, rel)))
            assert asm.pending == 0
        return nrec

    FUNCTIONS.register(f"strb-map:{tag}", mapper)
    FUNCTIONS.register(
        f"strb-reduce:{tag}", reducer_stream if streaming else reducer_sealed
    )


def _run_pipeline(tag: str, streaming: bool) -> float:
    """One full shuffle; returns wall seconds (records asserted complete)."""
    _register(tag, streaming)
    sess = Session(topology=_topology(), scheduler_mode="async", time_scale=TIME_SCALE)
    try:
        pa = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=N_MAP)
        pb = sess.start_pilot(resource_url=f"sim://{SITE_B}", slots=N_RED)
        pa.wait_active(), pb.wait_active()
        with Timer() as t:
            maps = []
            for m in range(N_MAP):
                outs = [
                    DataUnitDescription(
                        name=f"{tag}-m{m}-r{r}",
                        streaming=streaming,
                        ready_chunks=WINDOW,
                        chunk_size=CHUNK,
                    )
                    for r in range(N_RED)
                ]
                maps.append(
                    sess.submit_cu(
                        executable=f"strb-map:{tag}",
                        args=(m,),
                        output_data=outs,
                        affinity=SITE_A,
                    )
                )
            reduces = [
                sess.submit_cu(
                    executable=f"strb-reduce:{tag}",
                    input_data=[mf.outputs[r] for mf in maps],
                    affinity=SITE_B,
                )
                for r in range(N_RED)
            ]
            per_reducer = N_MAP * (N_RECORDS // N_RED)
            for red in reduces:
                assert red.result(timeout=240) == per_reducer, (
                    tag,
                    red.state,
                    red.error,
                )
            assert [m.result(timeout=60) for m in maps] == [N_RECORDS] * N_MAP
        return t.wall
    finally:
        sess.close()


def _run_exactly_once() -> bool:
    """A producer attempt crashes after streaming 2 chunks; the retry must
    fully replace them — the consumer-visible content is the winning
    attempt's alone."""
    attempts = []

    def flaky(cu_ctx):
        attempts.append(1)
        if len(attempts) == 1:
            cu_ctx.write_output("bad-0", b"B" * CHUNK)
            cu_ctx.write_output("bad-1", b"B" * CHUNK)
            assert cu_ctx.flush_output(0)  # two chunks live, then crash
            raise IOError("producer crash mid-stream")
        for i in range(3):
            cu_ctx.write_output(f"good-{i}", b"G" * CHUNK)
            assert cu_ctx.flush_output(0)
        return len(attempts)

    FUNCTIONS.register("strb-flaky", flaky)
    sess = Session(topology=_topology(), scheduler_mode="async", time_scale=TIME_SCALE)
    try:
        p = sess.start_pilot(resource_url=f"sim://{SITE_A}", slots=1)
        p.wait_active()
        out = sess.create_streaming_du(name="once", ready_chunks=1, chunk_size=CHUNK)
        cu = sess.submit_cu(executable="strb-flaky", max_retries=2, output_data=[out])
        ok = cu.result(timeout=120) == 2
        du = out.result(timeout=30)
        ok &= du.sealed and du.n_chunks == 3
        ok &= set(du.manifest) == {"good-0", "good-1", "good-2"}
        ok &= all(
            du.read(rel) == b"G" * CHUNK for rel in du.manifest
        )  # zero 'B' bytes survived the rollback
        ok &= sess.store.hget(f"du:{du.id}", "stream_writer") is None
        return bool(ok)
    finally:
        sess.close()


def run() -> List[str]:
    rows: List[str] = []
    sealed = _run_pipeline("sealed", streaming=False)
    stream = _run_pipeline("stream", streaming=True)
    rows.append(
        emit("streaming.sealed_pipeline.wall_s", sealed * 1e6, f"{sealed:.3f}s")
    )
    rows.append(
        emit(
            "streaming.streaming_pipeline.wall_s",
            stream * 1e6,
            f"{stream:.3f}s",
        )
    )
    rows.append(
        emit(
            "streaming.claim.streaming_beats_sealed_critical_path",
            0.0,
            f"{stream:.3f}<{sealed:.3f}:{stream < sealed}",
        )
    )
    once = _run_exactly_once()
    rows.append(
        emit(
            "streaming.claim.exactly_once_failed_attempt_rolls_back",
            0.0,
            f"retry-content-only:{once}",
        )
    )
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for _ in run():
        pass
