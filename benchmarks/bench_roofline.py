"""Roofline bench: report the three roofline terms per baselined dry-run
cell (reads experiments/dryrun artifacts; see EXPERIMENTS.md §Roofline)."""

from __future__ import annotations

from typing import List

from repro.launch.roofline import derive_terms, load_cells

from .common import emit


def run(mesh_name: str = None) -> List[str]:
    if mesh_name is None:
        # prefer the optimized variant when its artifacts exist
        mesh_name = (
            "pod_16x16__opt" if load_cells("pod_16x16__opt") else "pod_16x16"
        )
    rows = []
    cells = load_cells(mesh_name)
    if not cells:
        rows.append(
            emit("roofline.status", 0.0, "no dry-run artifacts yet (run dryrun --all)")
        )
        return rows
    n_ok = n_skip = n_fail = 0
    for cell in cells:
        if cell["status"] == "SKIP":
            n_skip += 1
            continue
        if cell["status"] != "OK":
            n_fail += 1
            continue
        t = derive_terms(cell)
        if not t:
            continue
        n_ok += 1
        step_s = max(t["compute_s"], t["memory_s"], t["collective_s"])
        rows.append(
            emit(
                f"roofline.{t['arch']}.{t['shape']}",
                step_s * 1e6,
                f"dom={t['dominant']};useful={t['useful_ratio']:.2f};"
                f"frac={t['roofline_frac']:.2f};fits={t['fits']}",
            )
        )
    rows.append(
        emit("roofline.cells", 0.0, f"ok={n_ok};skip={n_skip};fail={n_fail}")
    )
    return rows


if __name__ == "__main__":
    run()
