"""Quick-bench regression gate for CI.

Compares a fresh ``benchmarks/run.py --json`` artifact against the
committed baseline and fails (exit 1) when any *makespan* row regressed by
more than the threshold.  Makespans are simulated (deterministic transfer
clock), so a drift beyond the threshold means the scheduler/transfer code
path actually got slower, not that the runner was noisy.

Usage:
    python -m benchmarks.check_regression \
        --baseline benchmarks/baseline_quick.json \
        --current BENCH_<run>.json [--threshold 0.20]
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Dict

#: rows the gate compares: simulated makespans (and the replication /
#: staging T_R-class timings that feed them), plus the dataflow DAG's
#: deterministic critical-path staging totals
GATED = re.compile(r"\.makespan$|\.blocking_stage_sim$")


def load_rows(path: str) -> Dict[str, float]:
    with open(path, "r", encoding="utf-8") as fh:
        payload = json.load(fh)
    if payload.get("schema") != "bench-rows/v1":
        raise SystemExit(f"{path}: unexpected schema {payload.get('schema')!r}")
    return {
        r["name"]: float(r["us_per_call"])
        for r in payload["rows"]
        if GATED.search(r["name"])
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.20,
        help="max allowed fractional makespan regression (default 20%%)",
    )
    args = ap.parse_args()

    base = load_rows(args.baseline)
    cur = load_rows(args.current)
    if not base:
        raise SystemExit(f"{args.baseline}: no makespan rows to gate on")

    regressions = []
    missing = []
    print(f"{'row':<44} {'baseline':>12} {'current':>12} {'delta':>8}")
    for name, b in sorted(base.items()):
        if name not in cur:
            missing.append(name)
            continue
        c = cur[name]
        if b > 0:
            delta = (c - b) / b
        else:
            # a zero baseline is itself the claim (e.g. the async DAG's
            # blocking staging must stay 0): ANY growth is a regression
            delta = 0.0 if c <= 0 else float("inf")
        flag = " <-- REGRESSION" if delta > args.threshold else ""
        print(f"{name:<44} {b:>12.0f} {c:>12.0f} {delta:>+7.1%}{flag}")
        if delta > args.threshold:
            regressions.append((name, b, c, delta))
    for name in sorted(set(cur) - set(base)):
        print(f"{name:<44} {'(new)':>12} {cur[name]:>12.0f}        ")
    if missing:
        print(f"\nWARNING: {len(missing)} baseline row(s) missing from the "
              f"current run: {', '.join(missing)}", file=sys.stderr)
    if regressions:
        print(
            f"\nFAIL: {len(regressions)} makespan row(s) regressed more than "
            f"{args.threshold:.0%} — rebaseline only with a justification.",
            file=sys.stderr,
        )
        sys.exit(1)
    print(f"\nOK: no makespan regression beyond {args.threshold:.0%}.")


if __name__ == "__main__":
    main()
