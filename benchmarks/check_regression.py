"""Quick-bench regression gate for CI.

Compares a fresh ``benchmarks/run.py --json`` artifact against the
committed baseline and fails (exit 1) when any *makespan* row regressed by
more than the threshold.  Makespans are simulated (deterministic transfer
clock), so a drift beyond the threshold means the scheduler/transfer code
path actually got slower, not that the runner was noisy.

The gate also verifies every ``*.claim.*`` row in the CURRENT artifact
evaluates True — the recovery-path claims (no DU lost under churn, lineage
recomputation completes the DAG, monitor op counts O(changes)) gate PRs
exactly like scheduling regressions do.

With ``--markdown PATH`` the same per-row comparison (makespans and
claims, including failures) is appended to ``PATH`` as GitHub-flavored
tables — CI points this at ``$GITHUB_STEP_SUMMARY`` so every run shows the
current-vs-baseline table on the workflow summary page, pass or fail.

Usage:
    python -m benchmarks.check_regression \
        --baseline benchmarks/baseline_quick.json \
        --current BENCH_<run>.json [--threshold 0.20] [--markdown PATH]
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Dict

#: rows the gate compares: simulated makespans (and the replication /
#: staging T_R-class timings that feed them), plus the dataflow DAG's
#: deterministic critical-path staging totals
GATED = re.compile(r"\.makespan$|\.blocking_stage_sim$")

#: rows whose ``derived`` field is a True/False claim (the boolean is the
#: last colon-separated token, e.g. "800<=2x600(1.33x):True" or "True")
CLAIM = re.compile(r"\.claim\.")


def _load(path: str) -> dict:
    with open(path, "r", encoding="utf-8") as fh:
        payload = json.load(fh)
    if payload.get("schema") != "bench-rows/v1":
        raise SystemExit(f"{path}: unexpected schema {payload.get('schema')!r}")
    return payload


def load_rows(path: str) -> Dict[str, float]:
    return {
        r["name"]: float(r["us_per_call"])
        for r in _load(path)["rows"]
        if GATED.search(r["name"])
    }


def load_claims(path: str) -> Dict[str, str]:
    return {
        r["name"]: str(r["derived"])
        for r in _load(path)["rows"]
        if CLAIM.search(r["name"])
    }


def claim_holds(derived: str) -> bool:
    return derived.rsplit(":", 1)[-1].strip() == "True"


def write_markdown(
    path: str,
    compared: list,
    new_rows: list,
    claims: Dict[str, str],
    failed_claims: list,
    missing_claims: list,
    missing: list,
    threshold: float,
) -> None:
    """Append the comparison as GitHub-flavored tables (the CI bench job
    points this at ``$GITHUB_STEP_SUMMARY``)."""
    lines = ["## Quick-bench regression gate", ""]
    lines.append(
        f"Gated makespan rows vs baseline (threshold {threshold:.0%}):"
    )
    lines.append("")
    lines.append("| row | baseline (µs) | current (µs) | delta | status |")
    lines.append("| --- | ---: | ---: | ---: | --- |")
    for name, b, c, delta in compared:
        status = "❌ REGRESSION" if delta > threshold else "✅"
        shown = "inf" if delta == float("inf") else f"{delta:+.1%}"
        lines.append(f"| `{name}` | {b:.0f} | {c:.0f} | {shown} | {status} |")
    for name, c in new_rows:
        lines.append(f"| `{name}` | (new) | {c:.0f} | — | ✅ |")
    for name in missing:
        lines.append(f"| `{name}` | — | (missing) | — | ⚠️ |")
    lines.append("")
    lines.append(
        f"Claims: {len(claims)} checked, {len(failed_claims)} false, "
        f"{len(missing_claims)} missing."
    )
    lines.append("")
    lines.append("| claim | derived | status |")
    lines.append("| --- | --- | --- |")
    for name, derived in sorted(claims.items()):
        ok = claim_holds(derived)
        lines.append(
            f"| `{name}` | `{derived}` | {'✅' if ok else '❌ FALSE'} |"
        )
    for name in missing_claims:
        lines.append(f"| `{name}` | (missing from current run) | ❌ |")
    lines.append("")
    with open(path, "a", encoding="utf-8") as fh:
        fh.write("\n".join(lines) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.20,
        help="max allowed fractional makespan regression (default 20%%)",
    )
    ap.add_argument(
        "--markdown",
        default=None,
        metavar="PATH",
        help="append the comparison as a GitHub-flavored markdown table "
        "(for $GITHUB_STEP_SUMMARY)",
    )
    args = ap.parse_args()

    base = load_rows(args.baseline)
    cur = load_rows(args.current)
    if not base:
        raise SystemExit(f"{args.baseline}: no makespan rows to gate on")

    regressions = []
    missing = []
    compared = []
    print(f"{'row':<44} {'baseline':>12} {'current':>12} {'delta':>8}")
    for name, b in sorted(base.items()):
        if name not in cur:
            missing.append(name)
            continue
        c = cur[name]
        if b > 0:
            delta = (c - b) / b
        else:
            # a zero baseline is itself the claim (e.g. the async DAG's
            # blocking staging must stay 0): ANY growth is a regression
            delta = 0.0 if c <= 0 else float("inf")
        flag = " <-- REGRESSION" if delta > args.threshold else ""
        print(f"{name:<44} {b:>12.0f} {c:>12.0f} {delta:>+7.1%}{flag}")
        compared.append((name, b, c, delta))
        if delta > args.threshold:
            regressions.append((name, b, c, delta))
    new_rows = [(n, cur[n]) for n in sorted(set(cur) - set(base))]
    for name, c in new_rows:
        print(f"{name:<44} {'(new)':>12} {c:>12.0f}        ")
    if missing:
        print(f"\nWARNING: {len(missing)} baseline row(s) missing from the "
              f"current run: {', '.join(missing)}", file=sys.stderr)

    # claim gate: every claim in the current artifact must evaluate True,
    # and no claim the baseline knows may vanish from the current run
    claims = load_claims(args.current)
    baseline_claims = load_claims(args.baseline)
    failed_claims = sorted(
        name for name, derived in claims.items() if not claim_holds(derived)
    )
    missing_claims = sorted(set(baseline_claims) - set(claims))
    print(f"\nclaims: {len(claims)} checked, {len(failed_claims)} false")
    for name in failed_claims:
        print(f"  FALSE: {name} = {claims[name]}")
    if missing_claims:
        # a vanished claim is a failure, not a warning: the gate must not
        # pass silently exactly when the bench producing the claim broke
        print(
            f"\nFAIL: {len(missing_claims)} baseline claim(s) missing "
            f"from the current run: {', '.join(missing_claims)}",
            file=sys.stderr,
        )

    if args.markdown:
        # written BEFORE the exit decision: a failing run still gets its
        # table on the workflow summary page
        write_markdown(
            args.markdown,
            compared,
            new_rows,
            claims,
            failed_claims,
            missing_claims,
            missing,
            args.threshold,
        )

    if regressions or failed_claims or missing_claims:
        if regressions:
            print(
                f"\nFAIL: {len(regressions)} makespan row(s) regressed more "
                f"than {args.threshold:.0%} — rebaseline only with a "
                f"justification.",
                file=sys.stderr,
            )
        if failed_claims or missing_claims:
            print(
                f"\nFAIL: {len(failed_claims)} benchmark claim(s) evaluated "
                f"False, {len(missing_claims)} missing.",
                file=sys.stderr,
            )
        sys.exit(1)
    print(
        f"\nOK: no makespan regression beyond {args.threshold:.0%}; all "
        f"claims hold."
    )


if __name__ == "__main__":
    main()
