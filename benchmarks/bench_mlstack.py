"""ML-stack benchmark: the trainer/checkpointer/serving layers measured
ON the modern runtime (Session DAGs, healed DUs, tier cache).

Four cells, mirroring the ML-stack refactor's load-bearing claims:

  dag        — a trainer-shaped chunk chain (each chunk consumes
               [shard_i, ckpt_{i-1}] and seals ckpt_i) run two ways over
               the same data: the v1 submit-wait pattern vs one one-shot
               Session submission under the async scheduler, where a
               Waiting chunk's already-ready shard is prefetched while
               its checkpoint producer still computes.  Claim: the
               one-shot DAG's makespan beats sequential because shard
               staging leaves the critical path entirely.
  serve      — a serving fleet cold-starts N replicas from one checkpoint
               DU homed a WAN hop away.  With the mem-tier cache the warm
               accesses promote the DU into a hot site-local copy and the
               fleet stages from it; without, every replica pays the WAN.
  survival   — a checkpoint chain at ``replication_factor=2`` under the
               fault manager; the pilot that produced chunk 0 is killed
               the moment it finishes.  Claim: the run completes on the
               survivor, the FULL step count restores from the catalog,
               and the final checkpoint DU heals back to 2 replicas —
               no checkpoint-layer recovery code involved.
  scenario   — every model config in the registry becomes a cold-start
               scenario: a weights DU sized from ``cfg.param_count()``
               stages across the WAN and loads end-to-end.

Wall rows use ``time_scale`` (simulated seconds become real sleeps); the
``makespan``/``blocking_stage_sim`` rows are deterministic simulated
seconds and gate in CI via check_regression, as do all ``.claim.`` rows.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.checkpoint import Checkpointer, checkpoint_files
from repro.configs import get_config, list_archs
from repro.core import (
    CUState,
    DataUnitDescription,
    FUNCTIONS,
    Session,
    Topology,
)

from .common import MB, Timer, emit, modeled_makespan

DATA_SITE, COMPUTE_SITE = "ml:data", "ml:compute"
TIME_SCALE = 0.05

# ---- dag cell: 0.5 MB/s WAN → 4.2 s sim per 2 MB shard, 10 s sim compute
N_CHUNKS = 3
SHARD_BYTES = 2 * 1024 * 1024
SHARD_CHUNK = 256 * 1024
CKPT_BYTES = 16 * 1024
CHUNK_COMPUTE_S = 10.0

# ---- serve cell
N_REPLICAS = 4
WARM_LOADS = 2  # accesses needed to promote (tier_promote_after default)
SERVE_COMPUTE_S = 0.2
SERVE_ARCH = "h2o-danube-1.8b"

# ---- survival cell
KILL_RUN = "bm-kill"
KILL_CHUNKS = 3
KILL_COMPUTE_S = 30.0
KILL_TIME_SCALE = 0.01


def _two_site_topology(bandwidth: float) -> Topology:
    topo = Topology()
    topo.register(DATA_SITE, bandwidth=bandwidth, latency=0.05)
    topo.register(COMPUTE_SITE, bandwidth=bandwidth, latency=0.05)
    return topo


def _wait_until(pred, timeout=30.0, interval=0.01) -> bool:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return pred()


# ------------------------------------------------------------------- dag
def _register_chunk(tag: str) -> None:
    def train_chunk(cu_ctx):
        n = 0
        for du in cu_ctx.input_dus():
            for rel in du.manifest:
                n += len(cu_ctx.read_input(du.id, rel))
        cu_ctx.write_output("ck", b"K" * CKPT_BYTES)
        return n

    FUNCTIONS.register(f"bm-chunk:{tag}", train_chunk)


def _dag_setup(tag: str, mode: str) -> tuple:
    sess = Session(
        topology=_two_site_topology(0.5 * MB),
        scheduler_mode=mode,
        time_scale=TIME_SCALE,
    )
    pd = sess.start_pilot_data(
        service_url=f"sharedfs://{DATA_SITE}/shards-{tag}", affinity=DATA_SITE
    )
    pilot = sess.start_pilot(resource_url=f"sim://{COMPUTE_SITE}", slots=1)
    pilot.wait_active()
    shards = [
        sess.submit_du(
            name=f"shard-{tag}-{i}",
            files={"x.bin": bytes([i]) * SHARD_BYTES},
            chunk_size=SHARD_CHUNK,
            target=pd,
        )
        for i in range(N_CHUNKS)
    ]
    ck0 = sess.submit_du(name=f"ck0-{tag}", files={"ck": b"K" * CKPT_BYTES}, target=pd)
    [d.wait() for d in [*shards, ck0]]
    return sess, shards, ck0


def _dag_chunk(sess, tag: str, i: int, shard, prev_ckpt):
    return sess.submit_cu(
        executable=f"bm-chunk:{tag}",
        input_data=[shard, prev_ckpt],
        output_data=[DataUnitDescription(name=f"ck{i + 1}-{tag}")],
        sim_compute_s=CHUNK_COMPUTE_S,
    )


def _dag_collect(cus) -> Dict[str, float]:
    for cu in cus:
        assert cu.state == CUState.DONE, (cu.id, cu.state, cu.error)
    blocking = sum(cu.timings.sim_stage_s for cu in cus)
    compute = sum(cu.timings.sim_compute_s for cu in cus)
    prefetched = sum(cu.timings.sim_prefetch_s for cu in cus)
    # one pilot slot + a serial checkpoint chain: the modeled makespan is
    # the serial sum of every chunk's blocking stage + compute
    return {
        "blocking": blocking,
        "prefetched": prefetched,
        "makespan": blocking + compute,
    }


def _run_dag_sequential(tag: str) -> Dict[str, float]:
    """v1 pattern: submit a chunk, block on it, submit the next."""
    _register_chunk(tag)
    sess, shards, ck0 = _dag_setup(tag, "sync")
    try:
        cus, prev = [], ck0
        with Timer() as t:
            for i, shard in enumerate(shards):
                cu = _dag_chunk(sess, tag, i, shard, prev)
                assert cu.result(timeout=240) == SHARD_BYTES + CKPT_BYTES
                cus.append(cu)
                prev = cu.output
        stats = _dag_collect(cus)
        stats["wall"] = t.wall
        return stats
    finally:
        sess.close()


def _run_dag_oneshot(tag: str) -> Dict[str, float]:
    """The whole chunk chain submitted before any chunk runs; the async
    scheduler prefetches a Waiting chunk's ready shard input while its
    checkpoint producer computes."""
    _register_chunk(tag)
    sess, shards, ck0 = _dag_setup(tag, "async")
    try:
        cus, prev = [], ck0
        with Timer() as t:
            for i, shard in enumerate(shards):
                cu = _dag_chunk(sess, tag, i, shard, prev)
                cus.append(cu)
                prev = cu.output
            for cu in cus:
                assert cu.result(timeout=240) == SHARD_BYTES + CKPT_BYTES
        stats = _dag_collect(cus)
        stats["wall"] = t.wall
        return stats
    finally:
        sess.close()


# ----------------------------------------------------------------- serve
def _run_serve_fleet(tag: str, cached: bool) -> Dict[str, object]:
    cfg = get_config(SERVE_ARCH)
    n_f32 = max(16 * 1024, min(int(1 * MB), cfg.param_count() // 4096))
    weights = {"w": np.ones(n_f32, dtype=np.float32)}
    expect = float(n_f32)

    def load_weights(cu_ctx, weights_du):
        from repro.serving import params_from_input

        return float(params_from_input(cu_ctx, weights_du)["w"].sum())

    FUNCTIONS.register(f"bm-load:{tag}", load_weights)
    sess = Session(
        topology=_two_site_topology(2 * MB),
        tier_cache_bytes=(16 * n_f32) if cached else 0,
        tier_auto_promote=False,  # drained explicitly: deterministic
        time_scale=TIME_SCALE,
    )
    try:
        cold = sess.start_pilot_data(
            service_url=f"sharedfs://{DATA_SITE}/ckpt-{tag}", affinity=DATA_SITE
        )
        fleet = [
            sess.start_pilot(resource_url=f"sim://{COMPUTE_SITE}", slots=1)
            for _ in range(N_REPLICAS)
        ]
        for p in fleet:
            p.wait_active()
        du = Checkpointer(sess, run_name=f"bm-serve-{tag}").save(
            0, weights, target=cold
        )

        def _load(pilot):
            cu = sess.submit_cu(
                executable=f"bm-load:{tag}",
                args=(du.id,),
                input_data=[du],
                pilot=pilot,
                sim_compute_s=SERVE_COMPUTE_S,
                cache_inputs=cached,
            )
            assert cu.result(timeout=120) == expect
            return cu.timings.sim_stage_s + cu.timings.sim_compute_s

        with Timer() as t:
            # a canary replica's repeated loads heat the DU ...
            warm = [_load(fleet[0]) for _ in range(WARM_LOADS)]
            tm = sess.tier_manager
            if cached:
                tm.drain_promotions()
            # ... then the whole fleet cold-starts concurrently-shaped
            durs = [_load(p) for p in fleet]
        fleet_makespan = modeled_makespan(durs, slots=N_REPLICAS)
        cache_ids = {pd.id for pd in tm.cache_pds.values()}
        return {
            "warm": sum(warm),
            "fleet_makespan": fleet_makespan,
            "wall": t.wall,
            "promotions": tm.promotions_total,
            "promoted": bool(cache_ids & set(du.locations)),
        }
    finally:
        sess.close()


# -------------------------------------------------------------- survival
def _run_survival() -> Dict[str, object]:
    def train_chunk(cu_ctx, step):
        n = 0
        for du in cu_ctx.input_dus():
            n += sum(len(cu_ctx.read_input(du.id, r)) for r in du.manifest)
        files = checkpoint_files(
            step, KILL_RUN, {"w": np.full(16, float(step), np.float32)}
        )
        for rel, data in files.items():
            cu_ctx.write_output(rel, data)
        return n > 0

    FUNCTIONS.register("bm-survive", train_chunk)
    sess = Session(
        topology=_two_site_topology(10 * MB),
        enable_fault_manager=True,
        heartbeat_timeout_s=0.3,
        time_scale=KILL_TIME_SCALE,
    )
    try:
        sess.start_pilot_data(
            service_url=f"sharedfs://{DATA_SITE}/ck0", affinity=DATA_SITE
        )
        sess.start_pilot_data(
            service_url=f"sharedfs://{COMPUTE_SITE}/ck1", affinity=COMPUTE_SITE
        )
        pilots = [
            sess.start_pilot(resource_url=f"sim://{site}", slots=1)
            for site in (DATA_SITE, COMPUTE_SITE)
        ]
        for p in pilots:
            p.wait_active()
        by_id = {p.id: p for p in pilots}

        ck = Checkpointer(sess, run_name=KILL_RUN, replication_factor=2)
        du0 = ck.save(0, {"w": np.zeros(16, np.float32)})
        # the initial checkpoint disperses across both failure domains
        # BEFORE the kill, so recovery provably reads a replica
        assert _wait_until(lambda: len(du0.locations) >= 2, timeout=20), (
            f"replication_factor=2 not enforced: {du0.locations}"
        )

        cus, prev = [], du0
        killed: Dict[str, str] = {}

        def _kill_producer(fut):
            victim = by_id.get(fut.pilot_id)
            if victim is not None:
                killed["id"] = victim.id
                victim.fail()

        with Timer() as t:
            for i in range(KILL_CHUNKS):
                cu = sess.submit_cu(
                    executable="bm-survive",
                    args=(i + 1,),
                    input_data=[prev],
                    output_data=[
                        DataUnitDescription(
                            name=f"{KILL_RUN}.ck{i + 1}", replication_factor=2
                        )
                    ],
                    sim_compute_s=KILL_COMPUTE_S,
                    max_retries=4,
                )
                cus.append(cu)
                prev = cu.output
            # kill whichever pilot produced chunk 1 the moment it seals
            cus[0].add_done_callback(_kill_producer)
            for cu in cus:
                assert cu.result(timeout=240) is True
        for i, cu in enumerate(cus):
            sess.store.hset(f"ckpt:{KILL_RUN}", f"{i + 1:08d}", cu.output.id)
        survivor_ran = any(cu.pilot_id != killed.get("id") for cu in cus[1:])
        step, params, _ = ck.restore()
        restored = step == KILL_CHUNKS and float(params["w"][0]) == KILL_CHUNKS
        final = sess.ctx.lookup(cus[-1].output.id)
        healed = _wait_until(lambda: len(final.locations) >= 2, timeout=20)
        return {
            "wall": t.wall,
            "killed": killed.get("id", "<none>"),
            "survivor_ran": survivor_ran,
            "latest": ck.latest_step(),
            "restored": restored,
            "healed": healed,
            "replicas": len(final.locations),
        }
    finally:
        sess.close()


# -------------------------------------------------------------- scenario
def _run_scenarios(quick: bool) -> tuple:
    names = list_archs()
    if quick:
        names = [names[0], names[len(names) // 2], names[-1]]

    FUNCTIONS.register(
        "bm-scn-load",
        lambda cu_ctx: sum(
            len(cu_ctx.read_input(du.id, rel))
            for du in cu_ctx.input_dus()
            for rel in du.manifest
        ),
    )
    rows: List[str] = []
    n_ok = 0
    sess = Session(topology=_two_site_topology(10 * MB), time_scale=KILL_TIME_SCALE)
    try:
        cold = sess.start_pilot_data(
            service_url=f"sharedfs://{DATA_SITE}/scn", affinity=DATA_SITE
        )
        pilot = sess.start_pilot(resource_url=f"sim://{COMPUTE_SITE}", slots=1)
        pilot.wait_active()
        for name in names:
            cfg = get_config(name)
            # fp32 weights scaled to the simulated WAN: 1 byte per 512
            # real parameters, clamped to [64 KiB, 4 MB]
            nbytes = max(64 * 1024, min(int(4 * MB), cfg.param_count() // 512))
            du = sess.submit_du(
                name=f"w-{name}",
                files={"w": b"\0" * nbytes},
                chunk_size=512 * 1024,
                target=cold,
            ).result()
            cu = sess.submit_cu(
                executable="bm-scn-load",
                input_data=[du],
                pilot=pilot,
                sim_compute_s=0.05,
            )
            ok = cu.result(timeout=120) == nbytes
            n_ok += ok
            rows.append(
                emit(
                    f"mlstack.scenario.{name}.stage_sim",
                    cu.timings.sim_stage_s * 1e6,
                    f"params={cfg.param_count()};bytes={nbytes};ok={ok}",
                )
            )
    finally:
        sess.close()
    return rows, n_ok, len(names)


# ------------------------------------------------------------------- run
def run(quick: bool = False) -> List[str]:
    rows: List[str] = []

    # ---- one-shot training DAG vs v1 submit-wait
    seq = _run_dag_sequential("seq")
    one = _run_dag_oneshot("oneshot")
    for name, stats in (("sequential", seq), ("oneshot_async", one)):
        rows.append(
            emit(
                f"mlstack.dag.{name}.makespan",
                stats["makespan"] * 1e6,
                f"T={stats['makespan']:.2f}s",
            )
        )
        rows.append(
            emit(
                f"mlstack.dag.{name}.blocking_stage_sim",
                stats["blocking"] * 1e6,
                f"prefetched={stats['prefetched']:.2f}s",
            )
        )
        rows.append(emit(f"mlstack.dag.{name}.wall_s", stats["wall"] * 1e6, "info"))
    speedup = seq["makespan"] / max(one["makespan"], 1e-9)
    rows.append(
        emit(
            "mlstack.claim.oneshot_dag_beats_sequential",
            0.0,
            f"{one['makespan']:.2f}<{seq['makespan']:.2f}({speedup:.2f}x):"
            f"{one['makespan'] < seq['makespan']}",
        )
    )
    overlap_ok = one["blocking"] == 0.0 and one["prefetched"] > 0.0
    rows.append(
        emit(
            "mlstack.claim.chunk_staging_fully_overlapped",
            0.0,
            f"blocking={one['blocking']:.2f};"
            f"prefetched={one['prefetched']:.2f}:{overlap_ok}",
        )
    )
    wall_ok = one["wall"] < 1.1 * seq["wall"]
    rows.append(
        emit(
            "mlstack.claim.oneshot_wall_not_slower",
            0.0,
            f"{one['wall']:.2f}s<=1.1x{seq['wall']:.2f}s:{wall_ok}",
        )
    )

    # ---- tier-cached serving fleet cold-start
    hot = _run_serve_fleet("hot", cached=True)
    cold = _run_serve_fleet("cold", cached=False)
    for name, stats in (("cached", hot), ("uncached", cold)):
        rows.append(
            emit(
                f"mlstack.serve.{name}.makespan",
                stats["fleet_makespan"] * 1e6,
                f"T={stats['fleet_makespan']:.3f}s;warm={stats['warm']:.2f}s",
            )
        )
    speedup = cold["fleet_makespan"] / max(hot["fleet_makespan"], 1e-9)
    rows.append(
        emit(
            "mlstack.claim.tier_cached_fleet_beats_uncached",
            0.0,
            f"{hot['fleet_makespan']:.3f}<{cold['fleet_makespan']:.3f}"
            f"({speedup:.2f}x):"
            f"{hot['fleet_makespan'] < cold['fleet_makespan']}",
        )
    )
    promoted_ok = hot["promotions"] >= 1 and hot["promoted"]
    rows.append(
        emit(
            "mlstack.claim.hot_ckpt_promoted_to_mem_tier",
            0.0,
            f"promotions={hot['promotions']};in_cache={hot['promoted']}:"
            f"{promoted_ok}",
        )
    )

    # ---- checkpoint chain survives a mid-run pilot kill
    sv = _run_survival()
    rows.append(emit("mlstack.survival.wall_s", sv["wall"] * 1e6, "info"))
    survive_ok = (
        sv["killed"] != "<none>"
        and sv["survivor_ran"]
        and sv["latest"] == KILL_CHUNKS
        and sv["restored"]
    )
    rows.append(
        emit(
            "mlstack.claim.ckpt_chain_survives_pilot_kill",
            0.0,
            f"killed={sv['killed']};survivor_ran={sv['survivor_ran']};"
            f"latest={sv['latest']};restored={sv['restored']}:{survive_ok}",
        )
    )
    rows.append(
        emit(
            "mlstack.claim.ckpt_du_healed_to_factor",
            0.0,
            f"replicas={sv['replicas']}>=2:{sv['healed']}",
        )
    )

    # ---- every registry config as a cold-start scenario
    scn_rows, n_ok, n_total = _run_scenarios(quick)
    rows.extend(scn_rows)
    rows.append(
        emit(
            "mlstack.claim.config_scenarios_complete",
            0.0,
            f"{n_ok}/{n_total}:{n_ok == n_total}",
        )
    )
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
