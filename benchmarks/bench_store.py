"""Coordination-store throughput microbench — sharded vs legacy single-lock.

The PR-7 tentpole claim: the sharded coordination plane (striped locks,
out-of-lock queued event dispatch, group-commit WAL) outruns the legacy
architecture (one global lock, synchronous dispatch, per-op WAL flush) on
the write path, and the gap widens with writer concurrency.

Both configurations are the same class — the legacy mode is
``CoordinationStore(shards=1, dispatch="inline", wal_batch=1)``, which
reproduces the pre-shard architecture's costs: every mutation serializes on
one lock and pays a synchronous WAL write+flush before returning.  The
sharded default batches WAL records (group commit, flushed outside the
locks) and spreads keys across stripes, so the critical section is dict
work only.

Workload: each writer thread hammers ``hset`` over its own ``cu:`` key
range (the dominant mutation in the runtime: CU state transitions), with a
live WAL file on disk — the durability cost is part of the claim, not an
externality.
"""

from __future__ import annotations

import os
import tempfile
import threading
import time
from typing import Callable, List

from repro.core.coordination import CoordinationStore

from .common import emit

N_OPS_PER_WRITER = 5_000
KEYSPACE = 512  # keys per writer: steady-state update mix, not pure insert
MULTI_WRITERS = 4
REPEATS = 3


def _throughput(
    make_store: Callable[[str], CoordinationStore], n_writers: int
) -> float:
    """Best-of-repeats aggregate ops/s for ``n_writers`` threads."""
    best = 0.0
    for _ in range(REPEATS):
        with tempfile.TemporaryDirectory() as tmp:
            store = make_store(os.path.join(tmp, "wal.log"))
            barrier = threading.Barrier(n_writers + 1)

            def writer(tid: int) -> None:
                barrier.wait()
                for i in range(N_OPS_PER_WRITER):
                    store.hset(f"cu:w{tid}-{i % KEYSPACE}", "state", i)

            threads = [
                threading.Thread(target=writer, args=(t,))
                for t in range(n_writers)
            ]
            for t in threads:
                t.start()
            barrier.wait()
            t0 = time.perf_counter()
            for t in threads:
                t.join()
            elapsed = time.perf_counter() - t0
            store.close()
            best = max(best, n_writers * N_OPS_PER_WRITER / elapsed)
    return best


def _legacy(wal_path: str) -> CoordinationStore:
    return CoordinationStore(
        wal_path=wal_path, shards=1, dispatch="inline", wal_batch=1
    )


def _sharded(wal_path: str) -> CoordinationStore:
    # defaults: 16 stripes, queued dispatch, group-commit batch of 256
    return CoordinationStore(wal_path=wal_path)


def run() -> List[str]:
    rows = []
    results = {}
    for mode, factory in (("legacy", _legacy), ("sharded", _sharded)):
        for n in (1, MULTI_WRITERS):
            ops_s = _throughput(factory, n)
            results[(mode, n)] = ops_s
            rows.append(
                emit(
                    f"store.throughput.{mode}_{n}w",
                    1e6 / ops_s,  # µs per op
                    f"{ops_s / 1e3:.0f}kops/s",
                )
            )
    multi_ok = results[("sharded", MULTI_WRITERS)] > results[("legacy", MULTI_WRITERS)]
    single_ok = results[("sharded", 1)] > results[("legacy", 1)]
    rows.append(
        emit(
            "store.claim.sharded_beats_single_lock",
            0.0,
            f"{results[('sharded', MULTI_WRITERS)] / 1e3:.0f}k>"
            f"{results[('legacy', MULTI_WRITERS)] / 1e3:.0f}kops/s"
            f"@{MULTI_WRITERS}w:{multi_ok}",
        )
    )
    rows.append(
        emit(
            "store.claim.sharded_beats_single_lock_1writer",
            0.0,
            f"{results[('sharded', 1)] / 1e3:.0f}k>"
            f"{results[('legacy', 1)] / 1e3:.0f}kops/s@1w:{single_ok}",
        )
    )
    return rows


if __name__ == "__main__":
    run()
