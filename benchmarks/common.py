"""Shared benchmark infrastructure.

The paper's experiments ran on 2013 production grids (XSEDE/OSG) with
shared WAN links; this container is one CPU.  Benchmarks therefore run the
REAL Pilot-Data runtime (real scheduler decisions, real replica caching,
real bytes through the adaptors) with the **simulated transfer clock**
(DESIGN.md §2): per-transfer durations follow the topology edge weights and
backend profiles, calibrated to the paper's measured 2013-era WAN numbers.
Makespans are replayed from recorded per-CU (stage, compute) durations with
an m-slot list scheduler.
"""

from __future__ import annotations

import heapq
import time
from typing import Dict, Iterable

from repro.backends.base import BackendProfile

MB = 1e6
GB = 1e9

#: Fig.-7-calibrated backend profiles (2013 WAN-era): bandwidth bytes/s,
#: per-request setup seconds, catalog registration seconds.
PAPER_PROFILES: Dict[str, BackendProfile] = {
    # SRM + GridFTP: best bulk throughput, moderate setup
    "srm": BackendProfile(bandwidth=35 * MB, op_latency=2.0, register_latency=0.2),
    # plain SSH/scp: cheap setup, modest bandwidth
    "ssh": BackendProfile(bandwidth=12 * MB, op_latency=0.5),
    # Globus Online: GridFTP bandwidth behind a managed service層 overhead
    "globus_online": BackendProfile(
        bandwidth=30 * MB, op_latency=15.0, register_latency=1.0
    ),
    # iRODS: SSH-class transfer + catalog registration
    "irods": BackendProfile(bandwidth=12 * MB, op_latency=2.0, register_latency=0.5),
    # S3 over WAN: bandwidth-limited to the remote datacenter
    "s3": BackendProfile(bandwidth=6 * MB, op_latency=1.0, register_latency=0.1),
}


def modeled_makespan(
    durations: Iterable[float], slots: int, queue_time: float = 0.0
) -> float:
    """List-schedule task durations onto ``slots`` identical slots."""
    heap = [queue_time] * max(1, slots)
    heapq.heapify(heap)
    for d in durations:
        t = heapq.heappop(heap)
        heapq.heappush(heap, t + d)
    return max(heap)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.wall = time.perf_counter() - self.t0


def emit(name: str, us_per_call: float, derived: str) -> str:
    """One CSV row in the harness's required format."""
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line
