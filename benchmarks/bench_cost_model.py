"""§6.1 — the placement calculus as a decision engine.

Evaluates decide_placement against a brute-force oracle over randomized
(T_Q, data size, topology-bandwidth) instances: the paper's rule — move
compute to data when T_X > T_Q, else move data — should pick the pilot
minimizing completion-relevant cost.  Also sweeps
choose_replication_degree's incremental-replication behaviour.
"""

from __future__ import annotations

import random
from typing import List

from repro.core import (
    choose_replication_degree,
    decide_placement,
    estimate_tx,
    make_tpu_fleet_topology,
)

from .common import GB, emit


def run(n_instances: int = 500, seed: int = 7) -> List[str]:
    rng = random.Random(seed)
    topo, hosts = make_tpu_fleet_topology(pods=4, hosts_per_pod=4)
    optimal = 0
    regrets = []
    for _ in range(n_instances):
        data_loc = rng.choice(hosts)
        nbytes = int(rng.uniform(0.1, 64) * GB)
        pilots = [
            (f"p{i}", rng.choice(hosts), rng.uniform(0, 30.0))
            for i in range(rng.randint(2, 6))
        ]
        choices = decide_placement({data_loc: nbytes}, pilots, topo)
        # oracle: exhaustive min of T_Q + T_X
        oracle = min(
            tq + estimate_tx(nbytes, data_loc, loc, topo)
            for _, loc, tq in pilots
        )
        got = choices[0].score
        if abs(got - oracle) < 1e-9:
            optimal += 1
        regrets.append(got - oracle)
    frac = optimal / n_instances
    rows = [
        emit("cost_model.placement.optimal_fraction", 0.0, f"{frac:.3f}"),
        emit(
            "cost_model.placement.max_regret_s",
            0.0,
            f"{max(regrets):.4f}",
        ),
    ]
    # incremental replication: more tasks → more replicas chosen
    sites = [(f"cluster:pod{i}", 8) for i in range(4)]
    degrees = []
    for tasks in (1, 8, 64, 512):
        chosen = choose_replication_degree(
            nbytes=int(4 * GB),
            src="cluster:pod0",
            candidate_sites=sites,
            tasks=tasks,
            task_compute_s=30.0,
            topo=topo,
        )
        degrees.append(len(chosen))
        rows.append(
            emit(f"cost_model.replication_degree.tasks{tasks}", 0.0, str(len(chosen)))
        )
    rows.append(
        emit(
            "cost_model.claim.degree_monotone_in_demand",
            0.0,
            str(all(a <= b for a, b in zip(degrees, degrees[1:]))),
        )
    )
    return rows


if __name__ == "__main__":
    run()
