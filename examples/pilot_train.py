"""End-to-end driver: train a ~100M-param LM for a few hundred steps THROUGH
the Pilot-Data abstractions.

The run is ONE declaratively-submitted CU/DU DAG on the Session API: chunked
shard DUs (data), a checkpoint-DU chain (model state) wired future-to-future,
train-chunk CUs late-bound to pilots co-located with their inputs.  Every
checkpoint DU carries ``replication_factor=2`` — the runtime's ReplicaManager
disperses it across pods as it seals, so kill -9 any pilot mid-run and the
chunk replays from a surviving checkpoint replica (no trainer-side recovery
code).

Run (full, ~100M params, few hundred steps — takes a while on CPU):
  PYTHONPATH=src python examples/pilot_train.py --preset full
Run (demo, ~4M params, 30 steps, ~2 min):
  PYTHONPATH=src python examples/pilot_train.py
"""

import argparse
import dataclasses
import time

from repro.configs import get_config
from repro.configs.base import reduced
from repro.core import Session, make_tpu_fleet_topology
from repro.training.trainer import PilotTrainer

PRESETS = {
    # ~4M params — quick demo
    "demo": dict(
        model=dict(
            n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
            vocab_size=2048, head_dim=32,
        ),
        total_steps=30, chunk_steps=10, batch=8, seq=128,
        tokens_per_shard=200_000,
    ),
    # ~100M params — the assignment's end-to-end driver scale
    "full": dict(
        model=dict(
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
            vocab_size=32000, head_dim=64,
        ),
        total_steps=300, chunk_steps=25, batch=8, seq=256,
        tokens_per_shard=2_000_000,
    ),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="demo")
    args = ap.parse_args()
    preset = PRESETS[args.preset]

    cfg = reduced(get_config("h2o-danube-1.8b"), **preset["model"])
    cfg = dataclasses.replace(cfg, name=f"pilot-train-{args.preset}")
    print(f"model: {cfg.name} — {cfg.param_count()/1e6:.1f}M params")

    topo, _ = make_tpu_fleet_topology(pods=2, hosts_per_pod=1)
    with Session(
        topology=topo, enable_fault_manager=True, heartbeat_timeout_s=2.0
    ) as s:
        # data lives on each pod's shared FS; pilots on both pods
        s.start_pilot_data(
            service_url="sharedfs://cluster:pod0/scratch", affinity="cluster:pod0"
        )
        s.start_pilot_data(
            service_url="sharedfs://cluster:pod1/scratch", affinity="cluster:pod1"
        )
        s.start_pilot(resource_url="sim://cluster:pod0:host0", slots=1)
        s.start_pilot(resource_url="sim://cluster:pod1:host0", slots=1)

        tr = PilotTrainer(
            cfg,
            s,
            total_steps=preset["total_steps"],
            chunk_steps=preset["chunk_steps"],
            batch=preset["batch"],
            seq=preset["seq"],
            peak_lr=3e-3,
            n_shards=2,
            tokens_per_shard=preset["tokens_per_shard"],
            run_name=cfg.name,
            ckpt_replication=2,
        )
        tr.stage_data(affinities=["cluster:pod0", "cluster:pod1"])
        t0 = time.time()
        summary = tr.run(timeout_per_chunk=3600)
        dt = time.time() - t0
        print(f"\ntrained {summary['steps']} steps in {dt:.0f}s "
              f"({summary['chunks']} chunks on pilots {summary['pilots_used']})")
        print(f"loss: {summary['first_loss']:.3f} → {summary['final_loss']:.3f} "
              f"(improved={summary['improved']})")
        for h in summary["history"]:
            print(f"  chunk {h['chunk']:3d} steps={h['steps']} pilot={h['pilot']} "
                  f"loss_tail={h['losses'][-1]:.3f}")
        last = tr.ckpt_dus[-1]
        params = tr.restore_params()
        print(f"restored params from {last.url} "
              f"(replicas: {last.locations}): {len(params)} top-level entries")


if __name__ == "__main__":
    main()
