"""Quickstart: the Pilot-API in ~60 lines.

Creates a two-pod topology, allocates Pilot-Data and Pilot-Computes,
stages a Data-Unit, and runs Compute-Units whose placement the
Compute-Data Service decides by affinity — compute goes to the data.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    CUState,
    FUNCTIONS,
    PilotManager,
    make_tpu_fleet_topology,
)


def main() -> None:
    # 1. a logical resource topology (cluster → pods → hosts)
    topo, hosts = make_tpu_fleet_topology(pods=2, hosts_per_pod=2)
    mgr = PilotManager(topology=topo, enable_heartbeat_monitor=True)

    # 2. storage: one Pilot-Data on pod0's shared filesystem
    pd = mgr.start_pilot_data(
        service_url="sharedfs://cluster:pod0/scratch", affinity="cluster:pod0"
    )

    # 3. compute: pilots on both pods
    p0 = mgr.start_pilot(resource_url="sim://cluster:pod0:host0", slots=2)
    p1 = mgr.start_pilot(resource_url="sim://cluster:pod1:host0", slots=2)
    p0.wait_active(), p1.wait_active()

    # 4. data: a Data-Unit — location-transparent, immutable once staged
    du = mgr.submit_du(
        name="dataset", files={"part0.bin": b"x" * 4096, "part1.bin": b"y" * 4096}
    )
    du.wait()
    print(f"{du.url} staged at {du.locations} ({du.size} bytes)")

    # 5. work: CUs declare data deps; the CDS places them near the data
    @FUNCTIONS.register("wordcount")
    def wordcount(cu_ctx, part):
        return len(cu_ctx.read_input(du.id, part))

    cus = [
        mgr.submit_cu(
            executable="wordcount", args=(p,), input_data=[du.id]
        )
        for p in ("part0.bin", "part1.bin")
    ]
    mgr.wait()
    for cu in cus:
        assert cu.state == CUState.DONE
        print(f"{cu.url} ran on {cu.pilot_id}: result={cu.result}")

    # 6. the scheduler's reasoning is auditable
    for d in mgr.cds.decisions():
        print(
            f"decision: {d['cu']} → {d['pilot']} "
            f"(T_Q={d['t_q']:.3f}s, T_stage={d['t_stage']:.3f}s, {d['strategy']})"
        )
    mgr.shutdown()
    print("quickstart OK")


if __name__ == "__main__":
    main()
