"""Quickstart: the Pilot-API v2 in ~70 lines.

Creates a two-pod topology, allocates Pilot-Data and Pilot-Computes, and
submits a complete map → reduce DAG in ONE shot: CUs declare their data
dependencies by object (DUFutures chain into downstream input_data), the
runtime's DU-readiness gate sequences the stages, and the Compute-Data
Service places every CU by affinity — compute goes to the data.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    DataUnitDescription,
    FUNCTIONS,
    Session,
    make_tpu_fleet_topology,
)


def main() -> None:
    # 1. a logical resource topology (cluster → pods → hosts)
    topo, hosts = make_tpu_fleet_topology(pods=2, hosts_per_pod=2)
    with Session(topology=topo, enable_heartbeat_monitor=True) as s:
        # 2. storage: one Pilot-Data on pod0's shared filesystem
        s.start_pilot_data(
            service_url="sharedfs://cluster:pod0/scratch",
            affinity="cluster:pod0",
        )

        # 3. compute: pilots on both pods
        p0 = s.start_pilot(resource_url="sim://cluster:pod0:host0", slots=2)
        p1 = s.start_pilot(resource_url="sim://cluster:pod1:host0", slots=2)
        p0.wait_active(), p1.wait_active()

        # 4. executables: CUs resolve names through the function registry
        @FUNCTIONS.register("wordcount")
        def wordcount(cu_ctx, part):
            du = cu_ctx.input_dus()[0]
            n = len(cu_ctx.read_input(du.id, part))
            cu_ctx.write_output(f"count-{part}", str(n).encode())
            return n

        @FUNCTIONS.register("total")
        def total(cu_ctx):
            acc = 0
            for du in cu_ctx.input_dus():
                for rel in du.manifest:
                    acc += int(cu_ctx.read_input(du.id, rel))
            return acc

        # 5. the whole DAG, submitted upfront — no user-side waits:
        #    dataset → per-part wordcount CUs → gathering total CU
        dataset = s.submit_du(
            name="dataset",
            files={"part0.bin": b"x" * 4096, "part1.bin": b"y" * 4096},
        )
        counts = [
            s.submit_cu(
                executable="wordcount",
                args=(part,),
                input_data=[dataset],
                output_data=[DataUnitDescription(name=f"count-{part}")],
            )
            for part in ("part0.bin", "part1.bin")
        ]
        grand = s.submit_cu(
            executable="total", input_data=[c.output for c in counts]
        )
        print(f"total bytes counted: {grand.result(timeout=60)}")
        assert grand.result() == 8192
        for cu in counts:
            print(f"{cu.url} ran on {cu.pilot_id}: result={cu.result()}")
            print(f"  output {cu.output.url} replicated at {cu.output.locations}")

        # 6. the scheduler's reasoning is auditable
        for d in s.decisions():
            print(
                f"decision: {d['cu']} → {d['pilot']} "
                f"(T_Q={d['t_q']:.3f}s, T_stage={d['t_stage']:.3f}s, {d['strategy']})"
            )
    print("quickstart OK")


if __name__ == "__main__":
    main()
