"""The paper's §6.4 scenario as a runnable example: a task ensemble over
multiple sites, first WITHOUT and then WITH up-front DU replication —
replication unlocks the remote site (Fig. 11/12's lesson, at demo scale) —
and finally under the event-driven async scheduler, whose prefetch
pipeline moves input staging off the tasks' critical path.

Run:  PYTHONPATH=src python examples/distributed_ensemble.py
"""

import collections

from repro.core import (
    CUState,
    FUNCTIONS,
    Session,
    Topology,
    replicate_group,
)

MB = 1e6
N_TASKS = 32
TASK_COMPUTE_S = 120.0


def build_mgr(scheduler_mode="sync"):
    # bandwidths scaled so one task's input transfer ≈ one task's compute —
    # the paper's regime (9 GB at ~40 MB/s ≈ 225 s vs ~30 min tasks).  Real
    # file bytes stay small; the simulated clock carries the ratio.
    topo = Topology()
    topo.register("xsede:lonestar", bandwidth=3.3e3, latency=0.02)  # sim B/s
    topo.register("xsede:stampede", bandwidth=3.3e3, latency=0.02)
    sess = Session(topology=topo, scheduler_mode=scheduler_mode)
    FUNCTIONS.register("analyze", lambda cu_ctx: "done")
    return sess


def run(replicate: bool, scheduler_mode: str = "sync", remote_only: bool = False):
    """``remote_only``: compute exists only on Stampede while the data
    lives on Lonestar — every task must move its input, the regime where
    the async scheduler's prefetch pipeline pays off."""
    sess = build_mgr(scheduler_mode)
    pd_ls = sess.start_pilot_data(
        service_url="mem://xsede:lonestar/pd", affinity="xsede:lonestar"
    )
    pd_st = sess.start_pilot_data(
        service_url="mem://xsede:stampede/pd", affinity="xsede:stampede"
    )
    pilots = []
    if not remote_only:
        pilots.append(
            sess.start_pilot(resource_url="sim://xsede:lonestar", slots=4)
        )
    pilots.append(sess.start_pilot(resource_url="sim://xsede:stampede", slots=4))
    [p.wait_active() for p in pilots]

    dus = [
        sess.submit_du(
            name=f"input{i}",
            files={"data": b"d" * int(1.2 * MB)},
            target=pd_ls,
        )
        for i in range(N_TASKS)
    ]
    t_r = 0.0
    if replicate:
        for du in dus:
            t_r += replicate_group(du.du, pd_ls, [pd_st], sess.ctx)
    cus = [
        sess.submit_cu(
            executable="analyze",
            input_data=[du],
            sim_compute_s=TASK_COMPUTE_S,
        )
        for du in dus
    ]
    assert sess.wait(timeout=120)
    split = collections.Counter()
    stage_total = 0.0
    prefetch_total = 0.0
    for cu in cus:
        assert cu.state == CUState.DONE
        machine = sess.ctx.lookup(cu.pilot_id).affinity
        split[machine] += 1
        stage_total += cu.timings.sim_stage_s
        prefetch_total += cu.timings.sim_prefetch_s
    sess.close()
    return split, t_r, stage_total, prefetch_total


def main() -> None:
    split_no, _, stage_no, _ = run(replicate=False)
    split_yes, t_r, stage_yes, _ = run(replicate=True)
    print(f"without replication: split {dict(split_no)}, "
          f"total task staging {stage_no:.0f} sim-s")
    print(f"with replication   : split {dict(split_yes)}, "
          f"total task staging {stage_yes:.0f} sim-s (T_R={t_r:.0f} upfront)")
    # Paper Figs. 10/12: with co-located replicas, per-task download time is
    # eliminated — tasks link instead of transferring.
    assert stage_yes == 0.0, "replicated inputs should resolve as links"
    assert stage_no > 0.0, "non-replicated remote tasks must pay staging"
    # Remote-compute regime (data on Lonestar, pilots only on Stampede):
    # the sync agents pay staging on the critical path; the async
    # scheduler's pipeline prefetches it while earlier tasks execute.
    _, _, stage_sync_rem, _ = run(replicate=False, remote_only=True)
    _, _, stage_async_rem, prefetch_async = run(
        replicate=False, scheduler_mode="async", remote_only=True
    )
    print(f"remote sync        : blocking staging {stage_sync_rem:.0f} sim-s")
    print(f"remote async       : blocking staging {stage_async_rem:.0f} sim-s, "
          f"prefetched (overlapped) {prefetch_async:.0f} sim-s")
    assert stage_sync_rem > 0.0, "remote sync tasks must pay staging"
    assert prefetch_async > 0.0, "async mode should prefetch input staging"
    assert stage_async_rem < stage_sync_rem, (
        "prefetch should move staging off the critical path"
    )
    print("distributed_ensemble OK — replication eliminates per-task "
          "staging (paper Figs. 10/12); async prefetch overlaps the rest")


if __name__ == "__main__":
    main()
