"""The paper's §6.4 scenario as a runnable example: a task ensemble over
multiple sites, first WITHOUT and then WITH up-front DU replication —
replication unlocks the remote site (Fig. 11/12's lesson, at demo scale).

Run:  PYTHONPATH=src python examples/distributed_ensemble.py
"""

import collections

from repro.core import (
    CUState,
    DataUnitDescription,
    FUNCTIONS,
    PilotManager,
    Topology,
    replicate_group,
)

MB = 1e6
N_TASKS = 32
TASK_COMPUTE_S = 120.0


def build_mgr():
    # bandwidths scaled so one task's input transfer ≈ one task's compute —
    # the paper's regime (9 GB at ~40 MB/s ≈ 225 s vs ~30 min tasks).  Real
    # file bytes stay small; the simulated clock carries the ratio.
    topo = Topology()
    topo.register("xsede:lonestar", bandwidth=3.3e3, latency=0.02)  # sim B/s
    topo.register("xsede:stampede", bandwidth=3.3e3, latency=0.02)
    mgr = PilotManager(topology=topo)
    FUNCTIONS.register("analyze", lambda cu_ctx: "done")
    return mgr


def run(replicate: bool):
    mgr = build_mgr()
    pd_ls = mgr.start_pilot_data(
        service_url="mem://xsede:lonestar/pd", affinity="xsede:lonestar"
    )
    pd_st = mgr.start_pilot_data(
        service_url="mem://xsede:stampede/pd", affinity="xsede:stampede"
    )
    p_ls = mgr.start_pilot(resource_url="sim://xsede:lonestar", slots=4)
    p_st = mgr.start_pilot(resource_url="sim://xsede:stampede", slots=4)
    p_ls.wait_active(), p_st.wait_active()

    dus = [
        mgr.cds.submit_data_unit(
            DataUnitDescription(
                name=f"input{i}", files={"data": b"d" * int(1.2 * MB)}
            ),
            target=pd_ls,
        )
        for i in range(N_TASKS)
    ]
    t_r = 0.0
    if replicate:
        for du in dus:
            t_r += replicate_group(du, pd_ls, [pd_st], mgr.ctx)
    cus = [
        mgr.submit_cu(
            executable="analyze",
            input_data=[du.id],
            sim_compute_s=TASK_COMPUTE_S,
        )
        for du in dus
    ]
    assert mgr.wait(timeout=120)
    split = collections.Counter()
    stage_total = 0.0
    for cu in cus:
        assert cu.state == CUState.DONE
        machine = mgr.ctx.lookup(cu.pilot_id).affinity
        split[machine] += 1
        stage_total += cu.timings.sim_stage_s
    mgr.shutdown()
    return split, t_r, stage_total


def main() -> None:
    split_no, _, stage_no = run(replicate=False)
    split_yes, t_r, stage_yes = run(replicate=True)
    print(f"without replication: split {dict(split_no)}, "
          f"total task staging {stage_no:.0f} sim-s")
    print(f"with replication   : split {dict(split_yes)}, "
          f"total task staging {stage_yes:.0f} sim-s (T_R={t_r:.0f} upfront)")
    # Paper Figs. 10/12: with co-located replicas, per-task download time is
    # eliminated — tasks link instead of transferring.
    assert stage_yes == 0.0, "replicated inputs should resolve as links"
    assert stage_no > 0.0, "non-replicated remote tasks must pay staging"
    print("distributed_ensemble OK — replication eliminates per-task "
          "staging (paper Figs. 10/12)")


if __name__ == "__main__":
    main()
