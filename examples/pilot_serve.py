"""Serving driver: batched greedy decoding where the MODEL CHECKPOINT is a
replicated Data-Unit and each serving pilot loads it from its nearest
replica (checkpoint-as-DU is how multi-pod serving fleets warm up without
hammering one blob store).

Run:  PYTHONPATH=src python examples/pilot_serve.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer, load_checkpoint_du
from repro.configs import get_config
from repro.core import FUNCTIONS, Session, make_tpu_fleet_topology
from repro.models import build_model
from repro.serving import DecodeEngine


def main() -> None:
    cfg = get_config("gemma3-1b-smoke")  # reduced same-family config
    api = build_model(cfg)
    topo, _ = make_tpu_fleet_topology(pods=2, hosts_per_pod=1)
    mgr = Session(topology=topo)

    # "trained" params, checkpointed as a DU on pod0 and replicated to pod1
    pd0 = mgr.start_pilot_data(
        service_url="sharedfs://cluster:pod0/ckpt", affinity="cluster:pod0"
    )
    pd1 = mgr.start_pilot_data(
        service_url="sharedfs://cluster:pod1/ckpt", affinity="cluster:pod1"
    )
    params = api.init(jax.random.PRNGKey(0))
    ck = Checkpointer(mgr.ctx, run_name="serve-model", replicate_to=[pd1])
    du = ck.save(0, params, target=pd0)
    print(f"model checkpoint {du.url} replicated to {du.locations}")

    # serving CU on each pod: restore from the NEAREST replica, decode
    @FUNCTIONS.register("serve_batch")
    def serve_batch(cu_ctx, prompt_tokens, new_tokens):
        loc = cu_ctx.pilot.affinity
        _, p, _ = load_checkpoint_du(cu_ctx.ctx, cu_ctx.ctx.lookup(du.id), location=loc)
        p = jax.tree.map(jnp.asarray, p)
        engine = DecodeEngine(api, p, batch=len(prompt_tokens), max_len=64)
        out = engine.generate(jnp.asarray(prompt_tokens, jnp.int32), new_tokens)
        return np.asarray(out).tolist()

    for pod in (0, 1):
        mgr.start_pilot(resource_url=f"sim://cluster:pod{pod}:host0", slots=1)
    prompts = [[1, 5, 9, 2], [3, 3, 7, 1]]
    t0 = time.time()
    cus = [
        mgr.submit_cu(
            executable="serve_batch",
            args=(prompts, 8),
            input_data=[du],
            affinity=f"cluster:pod{pod}",
        )
        for pod in (0, 1)
    ]
    mgr.wait(timeout=300)
    for cu in cus:
        print(f"{cu.url} on {cu.pilot_id}: generated {cu.result()}")
    # both pods must decode identically from their local replicas
    assert cus[0].result() == cus[1].result(), "replica divergence!"
    print(f"served 2 pods in {time.time()-t0:.1f}s — replicas consistent ✓")
    mgr.close()


if __name__ == "__main__":
    main()
