"""Serving driver: a pilot fleet cold-starts decode replicas from ONE model
checkpoint DU.

The checkpoint is written once with ``replication_factor=2`` (the runtime's
ReplicaManager disperses it across pods as it seals), and every serve CU
declares it as ``input_data`` — so each replica's weight load goes through
the transfer service, feeds the TierManager's access stats, and after
``tier_promote_after`` loads the DU is PROMOTED into the site's mem-tier
cache: the rest of the fleet warms up from the hot in-memory replica instead
of re-pulling from the shared filesystem (checkpoint-as-DU is how multi-pod
serving fleets warm up without hammering one blob store).

Run:  PYTHONPATH=src python examples/pilot_serve.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.core import FUNCTIONS, Session, make_tpu_fleet_topology
from repro.models import build_model
from repro.serving import DecodeEngine, params_from_input


def main() -> None:
    cfg = get_config("gemma3-1b-smoke")  # reduced same-family config
    api = build_model(cfg)
    topo, _ = make_tpu_fleet_topology(pods=2, hosts_per_pod=2)
    with Session(
        topology=topo,
        enable_fault_manager=True,      # heals the ckpt DU to its factor
        tier_cache_bytes=256 * 1024 * 1024,
        tier_promote_after=2,           # promote on the 2nd load at a site
    ) as s:
        # "trained" params, checkpointed ONCE as a replicated DU
        s.start_pilot_data(
            service_url="sharedfs://cluster:pod0/ckpt", affinity="cluster:pod0"
        )
        s.start_pilot_data(
            service_url="sharedfs://cluster:pod1/ckpt", affinity="cluster:pod1"
        )
        params = api.init(jax.random.PRNGKey(0))
        ck = Checkpointer(s, run_name="serve-model", replication_factor=2)
        du = ck.save(0, params)
        deadline = time.time() + 10
        while time.time() < deadline and len(du.locations) < 2:
            time.sleep(0.05)
        print(f"model checkpoint {du.url} healed to {du.locations}")

        # serve executable: weights come from the DU declared as CU input —
        # the tier-cache-eligible cold-start path
        @FUNCTIONS.register("serve_batch")
        def serve_batch(cu_ctx, weights_du, prompt_tokens, new_tokens):
            p = jax.tree.map(jnp.asarray, params_from_input(cu_ctx, weights_du))
            engine = DecodeEngine(api, p, batch=len(prompt_tokens), max_len=64)
            out = engine.generate(jnp.asarray(prompt_tokens, jnp.int32), new_tokens)
            return np.asarray(out).tolist()

        # a fleet: two pilots per pod, one decode replica each
        for pod in (0, 1):
            for host in (0, 1):
                s.start_pilot(
                    resource_url=f"sim://cluster:pod{pod}:host{host}", slots=1
                )
        prompts = [[1, 5, 9, 2], [3, 3, 7, 1]]
        t0 = time.time()
        cus = [
            s.submit_cu(
                executable="serve_batch",
                args=(du.id, prompts, 8),
                input_data=[du],
                affinity=f"cluster:pod{pod}",
            )
            for pod in (0, 1)
            for _ in range(2)
        ]
        outs = [cu.result(timeout=300) for cu in cus]
        for cu, out in zip(cus, outs):
            print(f"{cu.url} on {cu.pilot_id}: generated {out}")
        # every replica must decode identically from its local copy
        assert all(o == outs[0] for o in outs), "replica divergence!"
        tm = s.tier_manager
        stats = tm.access_stats(du.id)
        print(
            f"served {len(cus)} replicas in {time.time()-t0:.1f}s — "
            f"consistent ✓  (ckpt DU accesses: {stats}, "
            f"mem-tier promotions: {tm.promotions_total})"
        )


if __name__ == "__main__":
    main()
